//! End-to-end driver: serve batched dynamic-length requests through BOTH
//! halves of the system, proving all layers compose.
//!
//!  A. The AOT path — the JAX/Pallas encoder block lowered by
//!     `python/compile/aot.py` into bucketed HLO artifacts, loaded by the
//!     Rust runtime, with §4.3-style host-side variant selection. Python is
//!     not involved at request time.
//!  B. The DISC-native path — the Rust transformer workload graph,
//!     bridged, constraint-collected, fused, and compiled to bucketed PJRT
//!     kernels by this repo's compiler.
//!
//! Both serve the same request-length stream; the report contrasts
//! latency/throughput and kernel/compile counters, and is recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run with: `make artifacts && cargo run --release --example serve_transformer`

use anyhow::Result;
use disc::bench::Table;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::coordinator::serve_closed_loop;
use disc::runtime::artifacts::{default_dir, register_gemms, AotTransformer};
use disc::runtime::pjrt::Device;
use disc::runtime::tensor::Tensor;
use disc::sim::GpuModel;
use disc::util::prng::Prng;
use std::time::Instant;

const REQUESTS: usize = 60;

fn main() -> Result<()> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---------- A. AOT JAX/Pallas path -----------------------------------
    let device = Device::cpu()?;
    let t0 = Instant::now();
    let mut aot = AotTransformer::load(&dir, &device)?;
    println!(
        "A. AOT path: loaded {} bucket variants (s={:?}) in {:.2?}",
        aot.variants.len(),
        aot.variants.iter().map(|v| v.bucket).collect::<Vec<_>>(),
        t0.elapsed()
    );

    let mut rng = Prng::new(2024);
    let lengths: Vec<usize> = (0..REQUESTS).map(|_| rng.range(8, 120)).collect();
    let inputs: Vec<Tensor> = lengths
        .iter()
        .map(|&n| Tensor::f32(&[n, aot.hidden], rng.fill_f32(n * aot.hidden, 1.0)))
        .collect();

    let t0 = Instant::now();
    let mut lat = Vec::with_capacity(REQUESTS);
    for x in &inputs {
        let t = Instant::now();
        let out = aot.run(x)?;
        lat.push(t.elapsed());
        assert_eq!(out.dims, vec![x.dims[0], aot.hidden]);
    }
    let wall = t0.elapsed();
    lat.sort();
    println!(
        "   served {REQUESTS} requests in {:.2?} ({:.1} req/s) p50={:.2?} p95={:.2?} \
         pad_copies={}",
        wall,
        REQUESTS as f64 / wall.as_secs_f64(),
        lat[REQUESTS / 2],
        lat[(REQUESTS * 95) / 100],
        aot.pad_copies,
    );

    // The §4.5 library entries from the same artifact bundle.
    let dev_rc = std::sync::Arc::new(Device::cpu()?);
    let mut lib = disc::library::GemmLibrary::new(dev_rc.clone());
    let n = register_gemms(&dir, &dev_rc, &mut lib)?;
    println!("   registered {n} pre-generated GEMM library entries (§4.5)");

    // ---------- B. DISC-native compiler path ------------------------------
    println!("\nB. DISC-native path: transformer workload through the compiler");
    let w = disc::workloads::transformer::workload();
    let compiler = DiscCompiler::new()?;
    let gpu = GpuModel::default();

    let mut table = Table::new(&[
        "mode", "wall", "req/s", "p50", "mem-kernels", "compiles", "T4 e2e (ms/req)",
    ]);
    for (label, mode) in [("eager (TF/PT)", Mode::Eager), ("disc", Mode::Disc)] {
        let module = disc::bridge::lower(&w.graph)?;
        let mut model = compiler.compile(module, &CompileOptions::mode(mode))?;
        // Warm the kernel caches (kernel compilation is a one-time cost,
        // measured separately by the compile_overhead bench).
        for inputs in w.request_stream(6, 98) {
            model.run(&inputs)?;
        }
        let stream = w.request_stream(REQUESTS, 99);
        let report = serve_closed_loop(&mut model, stream)?;
        let sim = gpu.breakdown(&report.metrics);
        table.row(&[
            label.to_string(),
            format!("{:.2?}", report.wall),
            format!("{:.1}", report.throughput_rps),
            format!("{:.2?}", report.p50),
            format!("{}", report.metrics.mem_kernels),
            format!("{}", report.metrics.compile_events),
            format!("{:.3}", sim.e2e_ms / REQUESTS as f64),
        ]);
    }
    table.print();

    // ---------- C. Cross-request batching ---------------------------------
    // A bursty open-loop flood, batching off vs on: same outputs, fewer
    // dispatches (see docs/runtime.md §Cross-request batching).
    println!("\nC. Cross-request batching under a bursty flood");
    for max_batch in [1usize, 8] {
        let module = disc::bridge::lower(&w.graph)?;
        let mut model = compiler.compile(module, &CompileOptions::mode(Mode::Disc))?;
        let opts = disc::coordinator::ServeOptions::rate(1_000_000.0)
            .bursty(REQUESTS)
            .batch(max_batch)
            .batch_window_us(200);
        let report =
            disc::coordinator::serve_open_loop(&mut model, w.request_stream(REQUESTS, 99), &opts)?;
        println!(
            "   batch={max_batch}: {} requests / {} dispatches (occupancy {:.2}) \
             kernels={} p99={:.2?}",
            report.completed,
            report.batch_launches,
            report.batch_occupancy,
            report.metrics.total_kernels(),
            report.p99,
        );
    }

    println!(
        "\nAll layers composed: Pallas kernels (L1) → JAX block (L2) → AOT HLO → \
         Rust runtime + DISC compiler (L3), Python never on the request path."
    );
    Ok(())
}
