//! Mixed static/dynamic compilation (§4.4): `Mode::Auto` sends fully-static
//! graphs to the static pipeline (exact-shape kernels, no masking/padding)
//! and dynamic graphs to the dynamic pipeline — "static shape compiler
//! engine could usually achieve better performance with the enriched
//! information".
//!
//! Run with: `cargo run --release --example static_fallback`

use anyhow::Result;
use disc::bench::measure;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::dhlo::DType;
use disc::graph::GraphBuilder;
use disc::runtime::tensor::Tensor;
use disc::util::prng::Prng;

fn build(static_rows: Option<usize>) -> disc::graph::Graph {
    let mut gb = GraphBuilder::new("fallback_demo");
    let rows = static_rows.map(|r| r as i64).unwrap_or(-1);
    let x = gb.placeholder("x", DType::F32, &[rows, 64]);
    let w = gb.weight("w", &[64, 64], 1);
    let g = gb.weight("g", &[64], 2);
    let b = gb.weight("b", &[64], 3);
    let h = gb.matmul("h", x, w);
    let act = gb.unary("act", disc::dhlo::UnKind::Gelu, h);
    let ln = gb.layernorm("ln", act, g, b);
    let sm = gb.softmax("sm", ln);
    gb.finish(&[sm])
}

fn main() -> Result<()> {
    let compiler = DiscCompiler::new()?;
    let mut rng = Prng::new(3);
    const ROWS: usize = 48;

    // Auto mode on a static graph → static pipeline.
    let static_module = disc::bridge::lower(&build(Some(ROWS)))?;
    let mut static_model = compiler.compile(static_module, &CompileOptions::mode(Mode::Auto))?;
    println!("static graph  → pipeline = {}", static_model.report.pipeline);

    // Auto mode on a dynamic graph → dynamic pipeline.
    let dyn_module = disc::bridge::lower(&build(None))?;
    let mut dyn_model = compiler.compile(dyn_module, &CompileOptions::mode(Mode::Auto))?;
    println!("dynamic graph → pipeline = {}", dyn_model.report.pipeline);

    // Fig. 4's question: with the SAME static input, how close does the
    // dynamic pipeline get to the static one?
    let input = Tensor::f32(&[ROWS, 64], rng.fill_f32(ROWS * 64, 1.0));
    let i2 = input.clone();
    let ms = measure("static", 5, 30, || {
        static_model.run(std::slice::from_ref(&input)).unwrap();
    });
    let md = measure("dynamic", 5, 30, || {
        dyn_model.run(std::slice::from_ref(&i2)).unwrap();
    });
    println!(
        "\nstatic pipeline : {:.3} ms/req\ndynamic pipeline: {:.3} ms/req \
         ({:.1}% of static performance)",
        ms.median_ms(),
        md.median_ms(),
        100.0 * ms.median_ms() / md.median_ms(),
    );
    println!(
        "\nThe gap comes from bucket padding + in-kernel masking — the \
         fig4_static_gap bench reproduces the paper's Figure 4 across \
         three workloads."
    );
    Ok(())
}
