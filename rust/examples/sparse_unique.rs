//! Sparse workload demo: `tf.Unique` produces *data-dependent* output
//! shapes (the paper's §2 sparse-workload motivation). DISC handles them
//! with a runtime-filled shape symbol; the kernel cache still converges
//! because bucketing keys on the unique-count bucket, not the exact count.
//!
//! Run with: `cargo run --release --example sparse_unique`

use anyhow::Result;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::util::prng::Prng;

fn main() -> Result<()> {
    let w = disc::workloads::ad_ranking::workload();
    let module = disc::bridge::lower(&w.graph)?;

    // Show the data-dependent symbol in the lowered IR.
    let uniq_line = disc::dhlo::print::print_module(&module)
        .lines()
        .find(|l| l.contains("unique"))
        .map(str::to_string)
        .unwrap_or_default();
    println!("lowered unique op: {}", uniq_line.trim());

    let compiler = DiscCompiler::new()?;
    let mut model = compiler.compile(module, &CompileOptions::mode(Mode::Disc))?;
    println!(
        "compiled ad_ranking: groups={} planned-kernels={}\n",
        model.report.fusion_groups, model.report.planned_kernels
    );

    let mut rng = Prng::new(5);
    println!("{:<10} {:>8} {:>12} {:>10}", "ids", "unique→", "kernels", "compiles");
    for list_len in [40usize, 80, 160, 320, 80, 160] {
        let inputs = (w.gen)(list_len, &mut rng);
        let out = model.run(&inputs)?;
        // The number of unique ids is data-dependent; recover it from the
        // run (scores are [BATCH, 1], so read the cache stats instead).
        println!(
            "{:<10} {:>8} {:>12} {:>10}",
            list_len,
            "(data-dep)",
            out.metrics.mem_kernels,
            out.metrics.compile_events,
        );
    }
    let cs = model.cache_stats().unwrap();
    println!(
        "\ncache: {} entries for 6 requests with data-dependent shapes; \
         {} hits — no per-shape recompilation.",
        cs.entries, cs.hits
    );
    Ok(())
}
