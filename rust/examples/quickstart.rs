//! Quickstart: build a dynamic-shape graph, compile it with DISC, and watch
//! the compile-once-per-pattern property over a stream of shapes.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::dhlo::{BinKind, DType, UnKind};
use disc::graph::GraphBuilder;
use disc::runtime::tensor::Tensor;
use disc::util::prng::Prng;

fn main() -> Result<()> {
    // 1. Author a framework-level graph with a dynamic leading dim (-1):
    //    y = layernorm(gelu(x @ W + b) + x') — a typical fused epilogue.
    let mut gb = GraphBuilder::new("quickstart");
    let x = gb.placeholder("x", DType::F32, &[-1, 64]);
    let w = gb.weight("w", &[64, 64], 1);
    let bias = gb.weight("b", &[64], 2);
    let gamma = gb.weight("gamma", &[64], 3);
    let beta = gb.weight("beta", &[64], 4);
    let h = gb.matmul("h", x, w);
    let hb = gb.bias_add("hb", h, bias);
    let act = gb.unary("act", UnKind::Gelu, hb);
    let res = gb.binary("res", BinKind::Add, act, x);
    let y = gb.layernorm("ln", res, gamma, beta);
    let graph = gb.finish(&[y]);

    // 2. Bridge to DHLO (constraints collected) and compile with DISC.
    let module = disc::bridge::lower(&graph)?;
    println!("--- lowered DHLO ({} instrs) ---", module.instrs.len());
    let compiler = DiscCompiler::new()?;
    let mut model = compiler.compile(module, &CompileOptions::mode(Mode::Disc))?;
    println!(
        "compiled: pipeline={} fusion-groups={} planned-kernels={}",
        model.report.pipeline, model.report.fusion_groups, model.report.planned_kernels
    );

    // 3. Serve a stream of *distinct* shapes: kernels compile only when a
    //    new (pattern, bucket) appears; repeats are pure cache hits.
    let mut rng = Prng::new(7);
    for n in [5usize, 9, 13, 17, 33, 50, 64, 100, 17, 33, 50] {
        let input = Tensor::f32(&[n, 64], rng.fill_f32(n * 64, 1.0));
        let out = model.run(&[input])?;
        let cs = model.cache_stats().unwrap();
        println!(
            "n={n:<4} out={:?} kernels={} compile_events={} (cache: {} entries, {} hits)",
            out.outputs[0].dims,
            out.metrics.mem_kernels,
            out.metrics.compile_events,
            cs.entries,
            cs.hits,
        );
    }
    let cs = model.cache_stats().unwrap();
    println!(
        "\n11 distinct requests, {} compiles total — DISC compiled once per \
         shape bucket, not once per shape.",
        cs.misses
    );
    Ok(())
}
