//! Microbench: PJRT launch and GEMM library cost decomposition.
//!
//! Run with: `cargo run --release --example perf_micro`

use disc::dhlo::{DType, Op};
use disc::library::GemmLibrary;
use disc::runtime::pjrt::Device;
use disc::runtime::reference::eval_op;
use disc::runtime::tensor::Tensor;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dev = std::sync::Arc::new(Device::cpu()?);
    let mut lib = GemmLibrary::new(dev);
    let a = Tensor::f32(&[176, 128], vec![0.5; 176 * 128]);
    let b = Tensor::f32(&[128, 128], vec![0.5; 128 * 128]);
    for _ in 0..5 {
        lib.matmul(&a, &b)?;
    }
    let t = Instant::now();
    let n = 100;
    for _ in 0..n {
        lib.matmul(&a, &b)?;
    }
    println!("lib 176x128x128 gemm: {:?}/call", t.elapsed() / n);

    // Batched GEMM through the same library.
    let a3 = Tensor::f32(&[4, 176, 44], vec![0.5; 4 * 176 * 44]);
    let b3 = Tensor::f32(&[4, 44, 176], vec![0.5; 4 * 44 * 176]);
    for _ in 0..5 {
        lib.matmul(&a3, &b3)?;
    }
    let t = Instant::now();
    for _ in 0..n {
        lib.matmul(&a3, &b3)?;
    }
    println!("lib 4x176x44x176 bgemm: {:?}/call", t.elapsed() / n);

    // Reference naive dot for comparison.
    let t = Instant::now();
    for _ in 0..20 {
        eval_op(&Op::Dot, &[&a, &b], &[176, 128], DType::F32)?;
    }
    println!("naive rust dot: {:?}/call", t.elapsed() / 20);
    Ok(())
}
