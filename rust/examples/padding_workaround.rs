//! The paper's §1 workaround, measured: "form tensors into a specific
//! shape with padding and slicing, which introduces redundant computations
//! and may lead to negative optimizations."
//!
//! Strategy A (workaround): freeze the graph at the maximum sequence
//! length, compile once statically, and pad *every* request up to it.
//! Strategy B (DISC): compile the dynamic graph; each request runs near
//! its own size.
//!
//! Run with: `cargo run --release --example padding_workaround`

use anyhow::Result;
use disc::bench::Table;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::runtime::tensor::Tensor;
use disc::sim::GpuModel;
use disc::util::prng::Prng;
use std::time::Instant;

const MAX_SEQ: usize = 160;
const REQUESTS: usize = 25;

fn main() -> Result<()> {
    let compiler = DiscCompiler::new()?;
    let w = disc::workloads::transformer::workload();
    let gpu = GpuModel::default();

    // Request lengths: mostly short, occasionally near max — the regime
    // where the padding workaround wastes the most compute.
    let mut rng = Prng::new(21);
    let lengths: Vec<usize> = (0..REQUESTS)
        .map(|_| if rng.chance(0.2) { rng.range(120, MAX_SEQ) } else { rng.range(32, 64) })
        .collect();

    // --- A: pad-to-max + static compile --------------------------------
    let frozen = disc::workloads::make_static(&w.graph, MAX_SEQ);
    let m_static = disc::bridge::lower(&frozen)?;
    let mut padded_model = compiler.compile(m_static, &CompileOptions::mode(Mode::Static))?;

    // --- B: DISC dynamic ------------------------------------------------
    let m_dyn = disc::bridge::lower(&w.graph)?;
    let mut disc_model = compiler.compile(m_dyn, &CompileOptions::mode(Mode::Disc))?;

    // Warm both.
    for &seq in &lengths[..4.min(lengths.len())] {
        let inputs = (w.gen)(seq, &mut rng);
        disc_model.run(&inputs)?;
        let padded = pad_request(&inputs, seq);
        padded_model.run(&padded)?;
    }

    let mut t = Table::new(&["strategy", "host wall", "flops", "mem bytes", "T4 device ms"]);
    for (label, pad) in [("pad-to-max (workaround)", true), ("DISC dynamic", false)] {
        let mut rng = Prng::new(99);
        let mut metrics = disc::runtime::metrics::RunMetrics::default();
        let t0 = Instant::now();
        for &seq in &lengths {
            let inputs = (w.gen)(seq, &mut rng);
            let out = if pad {
                padded_model.run(&pad_request(&inputs, seq))?
            } else {
                disc_model.run(&inputs)?
            };
            metrics += &out.metrics;
        }
        let b = gpu.breakdown(&metrics);
        t.row(&[
            label.to_string(),
            format!("{:.2?}", t0.elapsed()),
            format!("{:.1}M", metrics.flops as f64 / 1e6),
            disc::util::fmt_bytes(metrics.mem_bytes as usize),
            format!("{:.3}", b.comp_bound_ms + b.mem_bound_ms),
        ]);
    }
    t.print();
    println!(
        "\nPadding to max does redundant device work proportional to \
         (max/actual)² on attention — the paper's point: the workaround does \
         not solve the problem, it hides it in wasted FLOPs and bytes. (On \
         this CPU testbed the single-shape static pipeline has lower *host* \
         overhead; the device columns are what a GPU deployment pays.)"
    );
    Ok(())
}

/// Pad a transformer request (ids + positional encodings) to MAX_SEQ.
fn pad_request(inputs: &[Tensor], seq: usize) -> Vec<Tensor> {
    let ids = inputs[0].as_i64().unwrap();
    let pos = inputs[1].as_f32().unwrap();
    let hidden = inputs[1].dims[1];
    let mut ids_p = ids.to_vec();
    ids_p.resize(MAX_SEQ, 0);
    let mut pos_p = pos.to_vec();
    pos_p.resize(MAX_SEQ * hidden, 0.0);
    let _ = seq;
    vec![Tensor::i64(&[MAX_SEQ], ids_p), Tensor::f32(&[MAX_SEQ, hidden], pos_p)]
}
