//! Minimal offline reimplementation of the `anyhow` API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Errors are a chain of messages: wrapping with `context` pushes a new
//! frame. `{e}` displays the outermost message; `{e:#}` displays the whole
//! chain separated by `": "` (matching the upstream alternate formatting
//! the CLI and tests rely on).

use std::fmt;

/// A chainable, message-based error.
pub struct Error {
    /// Outermost message first; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what keeps the blanket `From` impl below
// coherent with the reflexive `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.context(c))
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("writing file").unwrap_err();
        assert_eq!(format!("{e:#}"), "writing file: disk on fire");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing key").unwrap_err()), "missing key");
        let r: Result<u32, Error> = Ok(7);
        assert_eq!(r.context("unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "too big");
        let e: Error = anyhow!("code {}", 42);
        assert_eq!(e.root_cause(), "code 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "disk on fire");
    }
}
