//! Pure-Rust stand-in for the `xla` PJRT bindings used by this workspace.
//!
//! The real deployment links XLA's PJRT C API; offline containers have no
//! such toolchain, so this vendored crate implements the same *interface*
//! over a small HLO-text parser and interpreter. It understands exactly the
//! instruction set the workspace's emitters produce (`codegen/hlo.rs`, the
//! GEMM library, and the AOT artifact modules): parameter, constant,
//! elementwise arithmetic, compare/select/convert, broadcast_in_dim,
//! transpose, iota, masked reduce with `to_apply` regions, dot (plain and
//! batched), pad (edge padding, negative amounts crop — the GEMM library's
//! device-side bucket adapter), copy, tuple and get-tuple-element.
//!
//! Semantics notes:
//! - layouts (`{1,0}` suffixes) are parsed and ignored: all data is
//!   row-major dense, which is what every caller assumes;
//! - `PjRtBuffer` is a "device"-resident value: executing with buffers
//!   (`execute_b`) moves no host memory, mirroring how the real PJRT keeps
//!   results on device until `to_literal_sync`.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// Crate-level error: a message string (the real bindings surface status
/// strings the same way).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Compile-time proof that the binding surface is `Send + Sync`: the real
/// PJRT client, loaded executables, and buffers are all thread-safe, and
/// the workspace's multi-worker runtime (shared kernel store, background
/// compile pool) relies on this stub matching that contract. Everything
/// here is plain owned data or `Arc`-shared immutable state, so the auto
/// traits hold structurally — this assertion keeps it that way.
const _: fn() = || {
    fn ok<T: Send + Sync>() {}
    ok::<PjRtClient>();
    ok::<PjRtLoadedExecutable>();
    ok::<PjRtBuffer>();
    ok::<Literal>();
    ok::<HloModuleProto>();
    ok::<XlaComputation>();
};

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// Element types the pipeline uses end-to-end.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S64,
    S32,
    Pred,
}

impl ElementType {
    fn name(self) -> &'static str {
        match self {
            ElementType::F32 => "f32",
            ElementType::S64 => "s64",
            ElementType::S32 => "s32",
            ElementType::Pred => "pred",
        }
    }

    fn from_name(s: &str) -> Result<ElementType> {
        Ok(match s {
            "f32" => ElementType::F32,
            "s64" => ElementType::S64,
            "s32" => ElementType::S32,
            "pred" => ElementType::Pred,
            other => return err(format!("unsupported element type '{other}'")),
        })
    }
}

/// Dense storage for one literal. Public only because [`NativeType`]'s
/// methods mention it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I64(Vec<i64>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
    Tuple(Vec<Literal>),
}

/// A host-resident tensor value (XLA literal).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Data,
}

/// Native Rust types that map onto [`ElementType`]s.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
    fn from_ne(bytes: &[u8]) -> Self;
    const WIDTH: usize;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    fn from_ne(b: &[u8]) -> f32 {
        f32::from_ne_bytes([b[0], b[1], b[2], b[3]])
    }
    const WIDTH: usize = 4;
}

impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
    fn wrap(v: Vec<i64>) -> Data {
        Data::I64(v)
    }
    fn unwrap(d: &Data) -> Option<&[i64]> {
        match d {
            Data::I64(v) => Some(v),
            _ => None,
        }
    }
    fn from_ne(b: &[u8]) -> i64 {
        i64::from_ne_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
    const WIDTH: usize = 8;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    fn from_ne(b: &[u8]) -> i32 {
        i32::from_ne_bytes([b[0], b[1], b[2], b[3]])
    }
    const WIDTH: usize = 4;
}

impl Literal {
    /// Rank-0 literal from a native scalar.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { ty: T::TY, dims: vec![], data: T::wrap(vec![v]) }
    }

    /// Build a literal by reinterpreting raw host bytes (the fast
    /// marshalling path the runtime uses).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        fn decode<T: NativeType>(dims: &[usize], data: &[u8], n: usize) -> Result<Literal> {
            if data.len() != n * T::WIDTH {
                return err(format!(
                    "untyped data length {} != {} elements × {} bytes",
                    data.len(),
                    n,
                    T::WIDTH
                ));
            }
            let v: Vec<T> = data.chunks_exact(T::WIDTH).map(T::from_ne).collect();
            Ok(Literal { ty: T::TY, dims: dims.to_vec(), data: T::wrap(v) })
        }
        match ty {
            ElementType::F32 => decode::<f32>(dims, data, n),
            ElementType::S64 => decode::<i64>(dims, data, n),
            ElementType::S32 => decode::<i32>(dims, data, n),
            ElementType::Pred => err("pred literals cannot be built from untyped data"),
        }
    }

    /// Copy the elements out as a native vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::unwrap(&self.data) {
            Some(v) => Ok(v.to_vec()),
            None => err(format!(
                "literal is {}, asked for {}",
                self.ty.name(),
                T::TY.name()
            )),
        }
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, Data::Tuple(vec![])) {
            Data::Tuple(parts) => Ok(parts),
            other => {
                self.data = other;
                err("literal is not a tuple")
            }
        }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Approximate host byte size of the payload.
    pub fn size_bytes(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len() * 4,
            Data::I64(v) => v.len() * 8,
            Data::I32(v) => v.len() * 4,
            Data::Pred(v) => v.len(),
            Data::Tuple(ps) => ps.iter().map(|p| p.size_bytes()).sum(),
        }
    }
}

/// A "device"-resident value. In this vendored backend the device is host
/// memory, but the type boundary is preserved: buffers flow between
/// executions without literal round-trips, exactly like real PJRT buffers.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Synchronous device→host readback.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }

    pub fn dims(&self) -> &[usize] {
        self.literal.dims()
    }

    pub fn element_type(&self) -> ElementType {
        self.literal.element_type()
    }

    pub fn size_bytes(&self) -> usize {
        self.literal.size_bytes()
    }
}

/// The PJRT client (CPU platform).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-interp".to_string()
    }

    /// Host→device transfer.
    pub fn buffer_from_host_literal(&self, lit: &Literal) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: lit.clone() })
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { module: Arc::new(comp.module.clone()) })
    }
}

/// Parsed HLO module "proto" (text-format backed).
pub struct HloModuleProto {
    module: HloModule,
}

impl HloModuleProto {
    /// Parse an HLO text file (the only parser the bundled XLA exposes).
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { module: parse_module(&text)? })
    }
}

/// A computation handle (mirrors the real binding's two-step build).
pub struct XlaComputation {
    module: HloModule,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.module.clone() }
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    module: Arc<HloModule>,
}

impl PjRtLoadedExecutable {
    /// Execute with host literals: transfers in, runs, leaves the result on
    /// "device". Shaped `result[replica][output]` like the real API.
    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let out = interpret(&self.module, &lits)?;
        Ok(vec![vec![PjRtBuffer { literal: out }]])
    }

    /// Execute with device-resident buffers (no host transfer).
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<&Literal> = args.iter().map(|a| &a.borrow().literal).collect();
        let out = interpret(&self.module, &lits)?;
        Ok(vec![vec![PjRtBuffer { literal: out }]])
    }
}

// ---------------------------------------------------------------------------
// HLO text parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct HloModule {
    computations: HashMap<String, Computation>,
    entry: String,
}

#[derive(Debug, Clone)]
struct Computation {
    instrs: Vec<Instr>,
    /// Index of the ROOT instruction.
    root: usize,
}

#[derive(Debug, Clone)]
struct Instr {
    name: String,
    ty: ParsedType,
    op: String,
    /// Operand names (empty for constant/parameter/iota).
    operands: Vec<String>,
    /// Raw text inside the parens for `constant`, raw index for `parameter`.
    raw: String,
    attrs: HashMap<String, String>,
}

#[derive(Debug, Clone)]
struct ParsedType {
    ty: ElementType,
    dims: Vec<usize>,
    /// Set for tuple-typed instructions; `ty`/`dims` are then unused.
    tuple: Option<Vec<ParsedType>>,
}

fn parse_module(text: &str) -> Result<HloModule> {
    let mut lines = text.lines();
    let header = loop {
        match lines.next() {
            Some(l) if l.trim().is_empty() => continue,
            Some(l) => break l.trim().to_string(),
            None => return err("empty module text"),
        }
    };
    if !header.starts_with("HloModule") {
        return err(format!("expected 'HloModule' header, got '{header}'"));
    }

    let mut computations = HashMap::new();
    let mut entry = String::new();
    let mut current: Option<(String, Vec<Instr>, Option<usize>, bool)> = None;
    for line in lines {
        let t = line.trim();
        if t.is_empty() || t.starts_with("//") {
            continue;
        }
        if t == "}" {
            let (name, instrs, root, is_entry) =
                current.take().ok_or_else(|| Error("unmatched '}'".into()))?;
            if instrs.is_empty() {
                return err(format!("computation '{name}' has no instructions"));
            }
            let root = root.unwrap_or(instrs.len() - 1);
            if is_entry {
                entry = name.clone();
            }
            computations.insert(name, Computation { instrs, root });
            continue;
        }
        if let Some(head) = t.strip_suffix('{') {
            // `name {` or `ENTRY name {`
            let head = head.trim();
            let (name, is_entry) = match head.strip_prefix("ENTRY ") {
                Some(rest) => (rest.trim().to_string(), true),
                None => (head.to_string(), false),
            };
            if current.is_some() {
                return err("nested computation block");
            }
            if name.is_empty() || name.contains(' ') {
                return err(format!("bad computation header '{t}'"));
            }
            current = Some((name, Vec::new(), None, is_entry));
            continue;
        }
        match current.as_mut() {
            Some((_, instrs, root, _)) => {
                let (ins, is_root) = parse_instr(t)?;
                if is_root {
                    *root = Some(instrs.len());
                }
                instrs.push(ins);
            }
            None => return err(format!("instruction outside computation: '{t}'")),
        }
    }
    if current.is_some() {
        return err("unterminated computation block");
    }
    if entry.is_empty() {
        return err("module has no ENTRY computation");
    }
    Ok(HloModule { computations, entry })
}

fn parse_instr(line: &str) -> Result<(Instr, bool)> {
    let (is_root, rest) = match line.strip_prefix("ROOT ") {
        Some(r) => (true, r),
        None => (false, line),
    };
    let eq = rest
        .find(" = ")
        .ok_or_else(|| Error(format!("instruction missing '=': '{line}'")))?;
    let name = rest[..eq].trim().to_string();
    let rhs = rest[eq + 3..].trim();
    let (ty, rhs) = parse_type(rhs)?;
    let rhs = rhs.trim_start();
    let open = rhs
        .find('(')
        .ok_or_else(|| Error(format!("missing '(' in '{line}'")))?;
    let op = rhs[..open].trim().to_string();
    let close = find_matching_paren(rhs, open)
        .ok_or_else(|| Error(format!("missing ')' in '{line}'")))?;
    let inside = rhs[open + 1..close].trim().to_string();
    let mut attrs = HashMap::new();
    let tail = rhs[close + 1..].trim();
    if !tail.is_empty() {
        let tail = tail.strip_prefix(',').unwrap_or(tail);
        for part in split_top_level(tail) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                Some((k, v)) => {
                    attrs.insert(k.trim().to_string(), v.trim().to_string());
                }
                None => return err(format!("bad attribute '{part}' in '{line}'")),
            }
        }
    }
    let (operands, raw) = if op == "constant" || op == "parameter" {
        (vec![], inside)
    } else {
        let ops: Vec<String> = split_top_level(&inside)
            .into_iter()
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        (ops, String::new())
    };
    Ok((Instr { name, ty, op, operands, raw, attrs }, is_root))
}

/// Parse a leading type out of `s`; returns the type and the remainder.
fn parse_type(s: &str) -> Result<(ParsedType, &str)> {
    let s = s.trim_start();
    if let Some(rest) = s.strip_prefix('(') {
        // Tuple type: `(f32[2]{0}, s32[])`.
        let mut parts = Vec::new();
        let mut rem = rest;
        loop {
            let (t, r) = parse_type(rem)?;
            parts.push(t);
            let r = r.trim_start();
            if let Some(r2) = r.strip_prefix(',') {
                rem = r2;
            } else if let Some(r2) = r.strip_prefix(')') {
                return Ok((
                    ParsedType { ty: ElementType::F32, dims: vec![], tuple: Some(parts) },
                    r2,
                ));
            } else {
                return err(format!("bad tuple type near '{r}'"));
            }
        }
    }
    let bracket = s
        .find('[')
        .ok_or_else(|| Error(format!("type missing '[': '{s}'")))?;
    let ty = ElementType::from_name(&s[..bracket])?;
    let end = s[bracket..]
        .find(']')
        .ok_or_else(|| Error(format!("type missing ']': '{s}'")))?
        + bracket;
    let dims_str = &s[bracket + 1..end];
    let mut dims = Vec::new();
    if !dims_str.trim().is_empty() {
        for d in dims_str.split(',') {
            dims.push(
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| Error(format!("bad dim '{d}' in '{s}'")))?,
            );
        }
    }
    let mut rest = &s[end + 1..];
    // Optional layout suffix `{...}` — parsed and ignored.
    if let Some(r) = rest.strip_prefix('{') {
        let close = r
            .find('}')
            .ok_or_else(|| Error(format!("unterminated layout in '{s}'")))?;
        rest = &r[close + 1..];
    }
    Ok((ParsedType { ty, dims, tuple: None }, rest))
}

fn find_matching_paren(s: &str, open: usize) -> Option<usize> {
    let bytes = s.as_bytes();
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Split on top-level commas (outside `{}`/`()` nesting).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '{' | '(' => {
                depth += 1;
                cur.push(c);
            }
            '}' | ')' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

fn parse_int_list(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    if inner.trim().is_empty() {
        return Ok(out);
    }
    for p in inner.split(',') {
        out.push(
            p.trim()
                .parse::<usize>()
                .map_err(|_| Error(format!("bad int list '{s}'")))?,
        );
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Interpretation
// ---------------------------------------------------------------------------

fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

fn interpret(module: &HloModule, args: &[&Literal]) -> Result<Literal> {
    let entry = module
        .computations
        .get(&module.entry)
        .ok_or_else(|| Error("entry computation missing".into()))?;
    let mut env: HashMap<&str, Literal> = HashMap::with_capacity(entry.instrs.len());
    for ins in &entry.instrs {
        let v = eval_instr(module, ins, args, &env)?;
        env.insert(ins.name.as_str(), v);
    }
    let root = &entry.instrs[entry.root];
    env.remove(root.name.as_str())
        .ok_or_else(|| Error("root value missing".into()))
}

fn get<'a>(env: &'a HashMap<&str, Literal>, name: &str) -> Result<&'a Literal> {
    env.get(name)
        .ok_or_else(|| Error(format!("operand '{name}' not yet computed")))
}

fn want_f32(l: &Literal) -> Result<&[f32]> {
    match &l.data {
        Data::F32(v) => Ok(v),
        _ => err(format!("expected f32 operand, got {}", l.ty.name())),
    }
}

fn want_pred(l: &Literal) -> Result<&[bool]> {
    match &l.data {
        Data::Pred(v) => Ok(v),
        _ => err(format!("expected pred operand, got {}", l.ty.name())),
    }
}

fn lit(ty: ElementType, dims: Vec<usize>, data: Data) -> Literal {
    Literal { ty, dims, data }
}

/// Numeric scalar view used by compare (total order comparisons on f64
/// are fine for the finite values that flow through the mask paths).
fn nth_as_f64(l: &Literal, i: usize) -> Result<f64> {
    Ok(match &l.data {
        Data::F32(v) => v[i] as f64,
        Data::I64(v) => v[i] as f64,
        Data::I32(v) => v[i] as f64,
        Data::Pred(v) => v[i] as u8 as f64,
        Data::Tuple(_) => return err("compare on tuple"),
    })
}

fn eval_instr(
    module: &HloModule,
    ins: &Instr,
    args: &[&Literal],
    env: &HashMap<&str, Literal>,
) -> Result<Literal> {
    let out_ty = ins.ty.ty;
    let out_dims = ins.ty.dims.clone();
    let n_out: usize = out_dims.iter().product();
    match ins.op.as_str() {
        "parameter" => {
            let idx: usize = ins
                .raw
                .trim()
                .parse()
                .map_err(|_| Error(format!("bad parameter index '{}'", ins.raw)))?;
            let a = args
                .get(idx)
                .ok_or_else(|| Error(format!("missing argument {idx}")))?;
            if a.dims != out_dims {
                return err(format!(
                    "argument {idx} shape {:?} != declared {:?}",
                    a.dims, out_dims
                ));
            }
            if a.ty != out_ty {
                return err(format!(
                    "argument {idx} type {} != declared {}",
                    a.ty.name(),
                    out_ty.name()
                ));
            }
            Ok((*a).clone())
        }
        "constant" => parse_constant(&ins.raw, out_ty, &out_dims),
        "copy" => Ok(get(env, &ins.operands[0])?.clone()),
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power" => {
            let a = get(env, &ins.operands[0])?;
            let b = get(env, &ins.operands[1])?;
            eval_binary(&ins.op, a, b, out_ty, out_dims)
        }
        "and" | "or" => {
            let a = want_pred(get(env, &ins.operands[0])?)?;
            let b = want_pred(get(env, &ins.operands[1])?)?;
            let v: Vec<bool> = a
                .iter()
                .zip(b)
                .map(|(&x, &y)| if ins.op == "and" { x && y } else { x || y })
                .collect();
            Ok(lit(ElementType::Pred, out_dims, Data::Pred(v)))
        }
        "negate" | "abs" | "exponential" | "log" | "tanh" | "sqrt" | "rsqrt" | "floor"
        | "sign" => {
            let x = get(env, &ins.operands[0])?;
            eval_unary(&ins.op, x, out_dims)
        }
        "compare" => {
            let a = get(env, &ins.operands[0])?;
            let b = get(env, &ins.operands[1])?;
            let dir = ins
                .attrs
                .get("direction")
                .ok_or_else(|| Error("compare missing direction".into()))?;
            let n = a.element_count();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let (x, y) = (nth_as_f64(a, i)?, nth_as_f64(b, i)?);
                v.push(match dir.as_str() {
                    "LT" => x < y,
                    "LE" => x <= y,
                    "GT" => x > y,
                    "GE" => x >= y,
                    "EQ" => x == y,
                    "NE" => x != y,
                    other => return err(format!("compare direction '{other}'")),
                });
            }
            Ok(lit(ElementType::Pred, out_dims, Data::Pred(v)))
        }
        "select" => {
            let p = want_pred(get(env, &ins.operands[0])?)?.to_vec();
            let t = get(env, &ins.operands[1])?;
            let f = get(env, &ins.operands[2])?;
            let data = match (&t.data, &f.data) {
                (Data::F32(a), Data::F32(b)) => Data::F32(
                    p.iter().enumerate().map(|(i, &c)| if c { a[i] } else { b[i] }).collect(),
                ),
                (Data::I64(a), Data::I64(b)) => Data::I64(
                    p.iter().enumerate().map(|(i, &c)| if c { a[i] } else { b[i] }).collect(),
                ),
                (Data::I32(a), Data::I32(b)) => Data::I32(
                    p.iter().enumerate().map(|(i, &c)| if c { a[i] } else { b[i] }).collect(),
                ),
                _ => return err("select branch dtype mismatch"),
            };
            Ok(lit(out_ty, out_dims, data))
        }
        "convert" => {
            let x = get(env, &ins.operands[0])?;
            eval_convert(x, out_ty, out_dims)
        }
        "broadcast" => {
            let x = get(env, &ins.operands[0])?;
            let mapping = parse_int_list(
                ins.attrs
                    .get("dimensions")
                    .ok_or_else(|| Error("broadcast missing dimensions".into()))?,
            )?;
            eval_broadcast(x, &mapping, out_ty, out_dims)
        }
        "transpose" => {
            let x = get(env, &ins.operands[0])?;
            let perm = parse_int_list(
                ins.attrs
                    .get("dimensions")
                    .ok_or_else(|| Error("transpose missing dimensions".into()))?,
            )?;
            eval_transpose(x, &perm, out_ty, out_dims)
        }
        "reshape" => {
            let x = get(env, &ins.operands[0])?;
            if x.element_count() != n_out {
                return err("reshape element count mismatch");
            }
            Ok(lit(out_ty, out_dims, x.data.clone()))
        }
        "iota" => {
            let axis: usize = ins
                .attrs
                .get("iota_dimension")
                .ok_or_else(|| Error("iota missing iota_dimension".into()))?
                .parse()
                .map_err(|_| Error("bad iota_dimension".into()))?;
            eval_iota(out_ty, out_dims, axis)
        }
        "reduce" => {
            let x = get(env, &ins.operands[0])?;
            let init = get(env, &ins.operands[1])?;
            let axes = parse_int_list(
                ins.attrs
                    .get("dimensions")
                    .ok_or_else(|| Error("reduce missing dimensions".into()))?,
            )?;
            let region = ins
                .attrs
                .get("to_apply")
                .ok_or_else(|| Error("reduce missing to_apply".into()))?;
            let fold = region_fold(module, region)?;
            eval_reduce(x, init, &axes, fold, out_ty, out_dims)
        }
        "dot" => {
            let a = get(env, &ins.operands[0])?;
            let b = get(env, &ins.operands[1])?;
            eval_dot(ins, a, b, out_dims)
        }
        "pad" => {
            let x = get(env, &ins.operands[0])?;
            let pv = get(env, &ins.operands[1])?;
            let cfg = ins
                .attrs
                .get("padding")
                .ok_or_else(|| Error("pad missing padding config".into()))?;
            eval_pad(x, pv, cfg, out_ty, out_dims)
        }
        "tuple" => {
            let parts: Vec<Literal> = ins
                .operands
                .iter()
                .map(|o| get(env, o).cloned())
                .collect::<Result<_>>()?;
            Ok(Literal { ty: ElementType::F32, dims: vec![], data: Data::Tuple(parts) })
        }
        "get-tuple-element" => {
            let x = get(env, &ins.operands[0])?;
            let idx: usize = ins
                .attrs
                .get("index")
                .ok_or_else(|| Error("get-tuple-element missing index".into()))?
                .parse()
                .map_err(|_| Error("bad tuple index".into()))?;
            match &x.data {
                Data::Tuple(parts) => parts
                    .get(idx)
                    .cloned()
                    .ok_or_else(|| Error("tuple index out of range".into())),
                _ => err("get-tuple-element on non-tuple"),
            }
        }
        other => err(format!("unsupported HLO opcode '{other}'")),
    }
}

fn parse_constant(raw: &str, ty: ElementType, dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    let flat: Vec<&str> = raw
        .split(|c| c == ',' || c == '{' || c == '}')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .collect();
    if flat.len() != n {
        return err(format!("constant has {} elements, type wants {n}", flat.len()));
    }
    let data = match ty {
        ElementType::F32 => {
            let mut v = Vec::with_capacity(n);
            for s in flat {
                v.push(match s {
                    "inf" => f32::INFINITY,
                    "-inf" => f32::NEG_INFINITY,
                    "nan" => f32::NAN,
                    _ => s.parse::<f32>().map_err(|_| Error(format!("bad f32 '{s}'")))?,
                });
            }
            Data::F32(v)
        }
        ElementType::S64 => Data::I64(
            flat.iter()
                .map(|s| s.parse::<i64>().map_err(|_| Error(format!("bad s64 '{s}'"))))
                .collect::<Result<_>>()?,
        ),
        ElementType::S32 => Data::I32(
            flat.iter()
                .map(|s| s.parse::<i32>().map_err(|_| Error(format!("bad s32 '{s}'"))))
                .collect::<Result<_>>()?,
        ),
        ElementType::Pred => Data::Pred(
            flat.iter()
                .map(|s| match *s {
                    "true" => Ok(true),
                    "false" => Ok(false),
                    _ => err(format!("bad pred '{s}'")),
                })
                .collect::<Result<_>>()?,
        ),
    };
    Ok(lit(ty, dims.to_vec(), data))
}

fn eval_binary(
    op: &str,
    a: &Literal,
    b: &Literal,
    out_ty: ElementType,
    out_dims: Vec<usize>,
) -> Result<Literal> {
    if a.dims != b.dims {
        return err(format!("binary {op}: shape mismatch {:?} vs {:?}", a.dims, b.dims));
    }
    let data = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            let f = |i: usize| -> f32 {
                let (p, q) = (x[i], y[i]);
                match op {
                    "add" => p + q,
                    "subtract" => p - q,
                    "multiply" => p * q,
                    "divide" => p / q,
                    "maximum" => p.max(q),
                    "minimum" => p.min(q),
                    _ => p.powf(q), // "power"
                }
            };
            Data::F32((0..x.len()).map(f).collect())
        }
        (Data::I64(x), Data::I64(y)) => {
            let f = |i: usize| -> Result<i64> {
                let (p, q) = (x[i], y[i]);
                Ok(match op {
                    "add" => p.wrapping_add(q),
                    "subtract" => p.wrapping_sub(q),
                    "multiply" => p.wrapping_mul(q),
                    "divide" => {
                        if q == 0 {
                            return err("integer division by zero");
                        }
                        p / q
                    }
                    "maximum" => p.max(q),
                    "minimum" => p.min(q),
                    other => return err(format!("binary {other} unsupported for s64")),
                })
            };
            Data::I64((0..x.len()).map(f).collect::<Result<_>>()?)
        }
        (Data::I32(x), Data::I32(y)) => {
            let f = |i: usize| -> Result<i32> {
                let (p, q) = (x[i], y[i]);
                Ok(match op {
                    "add" => p.wrapping_add(q),
                    "subtract" => p.wrapping_sub(q),
                    "multiply" => p.wrapping_mul(q),
                    "divide" => {
                        if q == 0 {
                            return err("integer division by zero");
                        }
                        p / q
                    }
                    "maximum" => p.max(q),
                    "minimum" => p.min(q),
                    other => return err(format!("binary {other} unsupported for s32")),
                })
            };
            Data::I32((0..x.len()).map(f).collect::<Result<_>>()?)
        }
        _ => return err(format!("binary {op}: dtype mismatch")),
    };
    Ok(lit(out_ty, out_dims, data))
}

fn eval_unary(op: &str, x: &Literal, out_dims: Vec<usize>) -> Result<Literal> {
    match &x.data {
        Data::F32(v) => {
            let f = |p: f32| -> f32 {
                match op {
                    "negate" => -p,
                    "abs" => p.abs(),
                    "exponential" => p.exp(),
                    "log" => p.ln(),
                    "tanh" => p.tanh(),
                    "sqrt" => p.sqrt(),
                    "rsqrt" => 1.0 / p.sqrt(),
                    "floor" => p.floor(),
                    // HLO sign: sign(±0) = ±0, sign(nan) = nan.
                    _ => {
                        if p > 0.0 {
                            1.0
                        } else if p < 0.0 {
                            -1.0
                        } else {
                            p
                        }
                    }
                }
            };
            Ok(lit(ElementType::F32, out_dims, Data::F32(v.iter().map(|&p| f(p)).collect())))
        }
        Data::I64(v) if op == "negate" => Ok(lit(
            ElementType::S64,
            out_dims,
            Data::I64(v.iter().map(|&p| -p).collect()),
        )),
        Data::I64(v) if op == "abs" => Ok(lit(
            ElementType::S64,
            out_dims,
            Data::I64(v.iter().map(|&p| p.abs()).collect()),
        )),
        Data::I32(v) if op == "negate" => Ok(lit(
            ElementType::S32,
            out_dims,
            Data::I32(v.iter().map(|&p| -p).collect()),
        )),
        _ => err(format!("unary {op}: unsupported dtype {}", x.ty.name())),
    }
}

fn eval_convert(x: &Literal, to: ElementType, out_dims: Vec<usize>) -> Result<Literal> {
    let n = x.element_count();
    let data = match to {
        ElementType::F32 => {
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(match &x.data {
                    Data::F32(d) => d[i],
                    Data::I64(d) => d[i] as f32,
                    Data::I32(d) => d[i] as f32,
                    Data::Pred(d) => d[i] as u8 as f32,
                    Data::Tuple(_) => return err("convert on tuple"),
                });
            }
            Data::F32(v)
        }
        ElementType::S64 => {
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(match &x.data {
                    Data::F32(d) => d[i] as i64,
                    Data::I64(d) => d[i],
                    Data::I32(d) => d[i] as i64,
                    Data::Pred(d) => d[i] as i64,
                    Data::Tuple(_) => return err("convert on tuple"),
                });
            }
            Data::I64(v)
        }
        ElementType::S32 => {
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(match &x.data {
                    Data::F32(d) => d[i] as i32,
                    Data::I64(d) => d[i] as i32,
                    Data::I32(d) => d[i],
                    Data::Pred(d) => d[i] as i32,
                    Data::Tuple(_) => return err("convert on tuple"),
                });
            }
            Data::I32(v)
        }
        ElementType::Pred => {
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(nth_as_f64(x, i)? != 0.0);
            }
            Data::Pred(v)
        }
    };
    Ok(lit(to, out_dims, data))
}

/// `broadcast_in_dim`: `mapping[i]` is the output axis operand axis `i`
/// occupies; unmapped output axes replicate.
fn eval_broadcast(
    x: &Literal,
    mapping: &[usize],
    out_ty: ElementType,
    out_dims: Vec<usize>,
) -> Result<Literal> {
    if mapping.len() != x.dims.len() {
        return err("broadcast mapping rank mismatch");
    }
    let n: usize = out_dims.iter().product();
    let in_strides = strides_of(&x.dims);
    let out_strides = strides_of(&out_dims);
    let mut src_index = vec![0usize; n];
    for (oi, s) in src_index.iter_mut().enumerate() {
        let mut acc = 0usize;
        for (i, &m) in mapping.iter().enumerate() {
            let coord = (oi / out_strides[m]) % out_dims[m];
            acc += coord * in_strides[i];
        }
        *s = acc;
    }
    let data = match &x.data {
        Data::F32(v) => Data::F32(src_index.iter().map(|&i| v[i]).collect()),
        Data::I64(v) => Data::I64(src_index.iter().map(|&i| v[i]).collect()),
        Data::I32(v) => Data::I32(src_index.iter().map(|&i| v[i]).collect()),
        Data::Pred(v) => Data::Pred(src_index.iter().map(|&i| v[i]).collect()),
        Data::Tuple(_) => return err("broadcast on tuple"),
    };
    Ok(lit(out_ty, out_dims, data))
}

/// `transpose`: output axis `i` draws from input axis `perm[i]`.
fn eval_transpose(
    x: &Literal,
    perm: &[usize],
    out_ty: ElementType,
    out_dims: Vec<usize>,
) -> Result<Literal> {
    if perm.len() != x.dims.len() {
        return err("transpose perm rank mismatch");
    }
    let n = x.element_count();
    let in_strides = strides_of(&x.dims);
    let out_strides = strides_of(&out_dims);
    let mut src_index = vec![0usize; n];
    for (oi, s) in src_index.iter_mut().enumerate() {
        let mut acc = 0usize;
        for (i, &p) in perm.iter().enumerate() {
            let coord = (oi / out_strides[i]) % out_dims[i];
            acc += coord * in_strides[p];
        }
        *s = acc;
    }
    let data = match &x.data {
        Data::F32(v) => Data::F32(src_index.iter().map(|&i| v[i]).collect()),
        Data::I64(v) => Data::I64(src_index.iter().map(|&i| v[i]).collect()),
        Data::I32(v) => Data::I32(src_index.iter().map(|&i| v[i]).collect()),
        Data::Pred(v) => Data::Pred(src_index.iter().map(|&i| v[i]).collect()),
        Data::Tuple(_) => return err("transpose on tuple"),
    };
    Ok(lit(out_ty, out_dims, data))
}

fn eval_iota(ty: ElementType, out_dims: Vec<usize>, axis: usize) -> Result<Literal> {
    let n: usize = out_dims.iter().product();
    if axis >= out_dims.len() && n > 1 {
        return err("iota axis out of range");
    }
    let strides = strides_of(&out_dims);
    let coord = |i: usize| -> usize {
        if out_dims.is_empty() {
            0
        } else {
            (i / strides[axis]) % out_dims[axis]
        }
    };
    let data = match ty {
        ElementType::S32 => Data::I32((0..n).map(|i| coord(i) as i32).collect()),
        ElementType::S64 => Data::I64((0..n).map(|i| coord(i) as i64).collect()),
        ElementType::F32 => Data::F32((0..n).map(|i| coord(i) as f32).collect()),
        ElementType::Pred => return err("pred iota unsupported"),
    };
    Ok(lit(ty, out_dims, data))
}

/// Resolve a reduce region to its scalar fold function by its ROOT opcode.
fn region_fold(module: &HloModule, name: &str) -> Result<fn(f32, f32) -> f32> {
    let comp = module
        .computations
        .get(name)
        .ok_or_else(|| Error(format!("region '{name}' not found")))?;
    let root = &comp.instrs[comp.root];
    Ok(match root.op.as_str() {
        "add" => |a, b| a + b,
        "multiply" => |a, b| a * b,
        "maximum" => |a: f32, b: f32| a.max(b),
        "minimum" => |a: f32, b: f32| a.min(b),
        other => return err(format!("unsupported reduce region root '{other}'")),
    })
}

fn eval_reduce(
    x: &Literal,
    init: &Literal,
    axes: &[usize],
    fold: fn(f32, f32) -> f32,
    out_ty: ElementType,
    out_dims: Vec<usize>,
) -> Result<Literal> {
    let v = want_f32(x)?;
    let init = want_f32(init)?[0];
    let n_out: usize = out_dims.iter().product();
    let kept: Vec<usize> = (0..x.dims.len()).filter(|a| !axes.contains(a)).collect();
    let in_strides = strides_of(&x.dims);
    let out_strides = strides_of(&out_dims);
    let mut out = vec![init; n_out];
    // Row-major scan over the input keeps the accumulation order
    // deterministic (and matches the reference interpreter's order).
    for (ii, &val) in v.iter().enumerate() {
        let mut oi = 0usize;
        for (k, &a) in kept.iter().enumerate() {
            let coord = (ii / in_strides[a]) % x.dims[a];
            oi += coord * out_strides[k];
        }
        out[oi] = fold(out[oi], val);
    }
    Ok(lit(out_ty, out_dims, Data::F32(out)))
}

fn eval_dot(ins: &Instr, a: &Literal, b: &Literal, out_dims: Vec<usize>) -> Result<Literal> {
    let av = want_f32(a)?;
    let bv = want_f32(b)?;
    let lc =
        parse_int_list(ins.attrs.get("lhs_contracting_dims").map(String::as_str).unwrap_or("{}"))?;
    let rc =
        parse_int_list(ins.attrs.get("rhs_contracting_dims").map(String::as_str).unwrap_or("{}"))?;
    let lb = parse_int_list(ins.attrs.get("lhs_batch_dims").map(String::as_str).unwrap_or("{}"))?;
    let rb = parse_int_list(ins.attrs.get("rhs_batch_dims").map(String::as_str).unwrap_or("{}"))?;
    if lc.len() != 1 || rc.len() != 1 || lb.len() > 1 || rb.len() != lb.len() {
        return err("dot: only single contracting (and at most one batch) dim supported");
    }
    match (a.dims.len(), b.dims.len(), lb.len()) {
        (2, 2, 0) => {
            // [m,k]·[k,n] with configurable contracted axes.
            let (lc, rc) = (lc[0], rc[0]);
            let (m_ax, n_ax) = (1 - lc, 1 - rc);
            let m = a.dims[m_ax];
            let k = a.dims[lc];
            let n = b.dims[n_ax];
            if b.dims[rc] != k {
                return err("dot: contracting extent mismatch");
            }
            let (sa, sb) = (strides_of(&a.dims), strides_of(&b.dims));
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for p in 0..k {
                        acc += av[i * sa[m_ax] + p * sa[lc]] * bv[p * sb[rc] + j * sb[n_ax]];
                    }
                    out[i * n + j] = acc;
                }
            }
            Ok(lit(ElementType::F32, out_dims, Data::F32(out)))
        }
        (3, 3, 1) => {
            if lb[0] != 0 || rb[0] != 0 || lc[0] != 2 || rc[0] != 1 {
                return err("dot: unsupported batched layout");
            }
            let (bs, m, k) = (a.dims[0], a.dims[1], a.dims[2]);
            let n = b.dims[2];
            if b.dims[0] != bs || b.dims[1] != k {
                return err("dot: batched extent mismatch");
            }
            let mut out = vec![0.0f32; bs * m * n];
            for t in 0..bs {
                for i in 0..m {
                    for j in 0..n {
                        let mut acc = 0.0f32;
                        for p in 0..k {
                            acc += av[(t * m + i) * k + p] * bv[(t * k + p) * n + j];
                        }
                        out[(t * m + i) * n + j] = acc;
                    }
                }
            }
            Ok(lit(ElementType::F32, out_dims, Data::F32(out)))
        }
        _ => err("dot: unsupported rank combination"),
    }
}

/// Edge padding (`padding=lo_hi[_int]x...`, one `x`-separated group per
/// axis). Negative lo/hi amounts crop, exactly like real HLO `pad`;
/// interior padding is not emitted by this workspace and is rejected.
fn eval_pad(
    x: &Literal,
    pv: &Literal,
    cfg: &str,
    out_ty: ElementType,
    out_dims: Vec<usize>,
) -> Result<Literal> {
    if pv.element_count() != 1 {
        return err("pad value must be a scalar");
    }
    let mut low: Vec<i64> = Vec::new();
    for (ax, group) in cfg.split('x').enumerate() {
        let parts: Vec<&str> = group.split('_').collect();
        if parts.len() < 2 || parts.len() > 3 {
            return err(format!("bad padding group '{group}'"));
        }
        let lo: i64 = parts[0].trim().parse().map_err(|_| Error(format!("bad pad low '{group}'")))?;
        let hi: i64 =
            parts[1].trim().parse().map_err(|_| Error(format!("bad pad high '{group}'")))?;
        if parts.len() == 3 && parts[2].trim() != "0" {
            return err("interior padding unsupported");
        }
        let src = *x
            .dims
            .get(ax)
            .ok_or_else(|| Error("padding config rank exceeds operand rank".into()))? as i64;
        let want = *out_dims
            .get(ax)
            .ok_or_else(|| Error("padding config rank exceeds output rank".into()))?
            as i64;
        if src + lo + hi != want {
            return err(format!(
                "pad axis {ax}: {src} + {lo} + {hi} != declared {want}"
            ));
        }
        low.push(lo);
    }
    if low.len() != x.dims.len() {
        return err("padding config rank mismatch");
    }

    fn fill<T: Copy>(
        src: &[T],
        src_dims: &[usize],
        init: T,
        low: &[i64],
        out_dims: &[usize],
    ) -> Vec<T> {
        let n_out: usize = out_dims.iter().product();
        let mut out = vec![init; n_out];
        let sstr = strides_of(src_dims);
        let ostr = strides_of(out_dims);
        'el: for (si, &v) in src.iter().enumerate() {
            let mut off = 0usize;
            for ax in 0..src_dims.len() {
                let c = (si / sstr[ax]) % src_dims[ax];
                let oc = c as i64 + low[ax];
                if oc < 0 || oc >= out_dims[ax] as i64 {
                    continue 'el;
                }
                off += oc as usize * ostr[ax];
            }
            out[off] = v;
        }
        out
    }

    let data = match (&x.data, &pv.data) {
        (Data::F32(v), Data::F32(p)) => Data::F32(fill(v, &x.dims, p[0], &low, &out_dims)),
        (Data::I64(v), Data::I64(p)) => Data::I64(fill(v, &x.dims, p[0], &low, &out_dims)),
        (Data::I32(v), Data::I32(p)) => Data::I32(fill(v, &x.dims, p[0], &low, &out_dims)),
        (Data::Pred(v), Data::Pred(p)) => Data::Pred(fill(v, &x.dims, p[0], &low, &out_dims)),
        _ => return err("pad: operand/value dtype mismatch"),
    };
    Ok(lit(out_ty, out_dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compile(text: &str) -> PjRtLoadedExecutable {
        let dir = std::env::temp_dir().join(format!("xla_stub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!(
            "m{}.hlo.txt",
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::write(&path, text).unwrap();
        let proto = HloModuleProto::from_text_file(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let comp = XlaComputation::from_proto(&proto);
        PjRtClient::cpu().unwrap().compile(&comp).unwrap()
    }

    fn f32_lit(dims: &[usize], v: Vec<f32>) -> Literal {
        let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_ne_bytes()).collect();
        Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, &bytes).unwrap()
    }

    #[test]
    fn elementwise_chain() {
        let exe = compile(
            "HloModule t, entry_computation_layout={(f32[4]{0})->f32[4]{0}}\n\n\
             ENTRY main {\n  p0 = f32[4]{0} parameter(0)\n  t = f32[4]{0} tanh(p0)\n  ROOT a = f32[4]{0} add(p0, t)\n}\n",
        );
        let x = f32_lit(&[4], vec![0.0, 0.5, -1.0, 2.0]);
        let out = exe.execute(&[x.clone()]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        for (o, i) in v.iter().zip(x.to_vec::<f32>().unwrap()) {
            assert!((o - (i + i.tanh())).abs() < 1e-6);
        }
    }

    #[test]
    fn masked_reduce_matches_hand_computation() {
        let exe = compile(
            "HloModule m, entry_computation_layout={(f32[2,4]{1,0}, s32[])->f32[2]{0}}\n\n\
             region_add {\n  ra = f32[] parameter(0)\n  rb = f32[] parameter(1)\n  ROOT rr = f32[] add(ra, rb)\n}\n\n\
             ENTRY main {\n  p0 = f32[2,4]{1,0} parameter(0)\n  n = s32[] parameter(1)\n  i = s32[2,4]{1,0} iota(), iota_dimension=1\n  nb = s32[2,4]{1,0} broadcast(n), dimensions={}\n  mask = pred[2,4]{1,0} compare(i, nb), direction=LT\n  zero = f32[] constant(0)\n  zb = f32[2,4]{1,0} broadcast(zero), dimensions={}\n  masked = f32[2,4]{1,0} select(mask, p0, zb)\n  init = f32[] constant(0)\n  ROOT r = f32[2]{0} reduce(masked, init), dimensions={1}, to_apply=region_add\n}\n",
        );
        let x = f32_lit(&[2, 4], vec![1., 2., 3., 999., 4., 5., 6., 999.]);
        let n = Literal::scalar(3i32);
        let out = exe.execute(&[x, n]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![6.0, 15.0]);
    }

    #[test]
    fn dot_and_batched_dot() {
        let exe = compile(
            "HloModule g, entry_computation_layout={(f32[2,3]{1,0}, f32[3,2]{1,0})->f32[2,2]{1,0}}\n\n\
             ENTRY main {\n  a = f32[2,3]{1,0} parameter(0)\n  b = f32[3,2]{1,0} parameter(1)\n  ROOT d = f32[2,2]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n",
        );
        let a = f32_lit(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = f32_lit(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let out = exe.execute(&[a, b]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![58., 64., 139., 154.]);

        let bexe = compile(
            "HloModule bg, entry_computation_layout={(f32[2,1,2]{2,1,0}, f32[2,2,1]{2,1,0})->f32[2,1,1]{2,1,0}}\n\n\
             ENTRY main {\n  a = f32[2,1,2]{2,1,0} parameter(0)\n  b = f32[2,2,1]{2,1,0} parameter(1)\n  ROOT d = f32[2,1,1]{2,1,0} dot(a, b), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={1}\n}\n",
        );
        let a = f32_lit(&[2, 1, 2], vec![1., 2., 3., 4.]);
        let b = f32_lit(&[2, 2, 1], vec![1., 1., 2., 2.]);
        let out = bexe.execute(&[a, b]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![3., 14.]);
    }

    #[test]
    fn execute_b_keeps_values_on_device() {
        let exe = compile(
            "HloModule t, entry_computation_layout={(f32[2]{0})->f32[2]{0}}\n\n\
             ENTRY main {\n  p0 = f32[2]{0} parameter(0)\n  ROOT n = f32[2]{0} negate(p0)\n}\n",
        );
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_literal(&f32_lit(&[2], vec![1.0, -2.0])).unwrap();
        let once = exe.execute_b(&[&buf]).unwrap();
        let twice = exe.execute_b(&[&once[0][0]]).unwrap();
        let v = twice[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1.0, -2.0]);
    }

    #[test]
    fn pad_grows_with_value_and_negative_amounts_crop() {
        // Grow [2,3] -> [4,4] with zeros.
        let exe = compile(
            "HloModule p, entry_computation_layout={(f32[2,3]{1,0})->f32[4,4]{1,0}}\n\n\
             ENTRY main {\n  p0 = f32[2,3]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT o = f32[4,4]{1,0} pad(p0, z), padding=0_2x0_1\n}\n",
        );
        let a = f32_lit(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let out = exe.execute(&[a]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(
            v,
            vec![1., 2., 3., 0., 4., 5., 6., 0., 0., 0., 0., 0., 0., 0., 0., 0.]
        );

        // Negative high amount crops [2,3] -> [2,2].
        let exe = compile(
            "HloModule c, entry_computation_layout={(f32[2,3]{1,0})->f32[2,2]{1,0}}\n\n\
             ENTRY main {\n  p0 = f32[2,3]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT o = f32[2,2]{1,0} pad(p0, z), padding=0_0x0_-1\n}\n",
        );
        let a = f32_lit(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let out = exe.execute(&[a]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1., 2., 4., 5.]);
    }

    #[test]
    fn garbage_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("xla_stub_garbage_{}.txt", std::process::id()));
        std::fs::write(&path, "not hlo at all").unwrap();
        assert!(HloModuleProto::from_text_file(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transpose_and_broadcast() {
        let exe = compile(
            "HloModule tb, entry_computation_layout={(f32[2,3]{1,0})->f32[3,2]{1,0}}\n\n\
             ENTRY main {\n  p0 = f32[2,3]{1,0} parameter(0)\n  ROOT t = f32[3,2]{1,0} transpose(p0), dimensions={1,0}\n}\n",
        );
        let a = f32_lit(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let out = exe.execute(&[a]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1., 4., 2., 5., 3., 6.]);

        let bexe = compile(
            "HloModule b, entry_computation_layout={(f32[3]{0})->f32[2,3]{1,0}}\n\n\
             ENTRY main {\n  p0 = f32[3]{0} parameter(0)\n  ROOT b = f32[2,3]{1,0} broadcast(p0), dimensions={1}\n}\n",
        );
        let a = f32_lit(&[3], vec![1., 2., 3.]);
        let out = bexe.execute(&[a]).unwrap();
        let v = out[0][0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1., 2., 3., 1., 2., 3.]);
    }
}
