//! Figure 3: DISC speedup over TensorFlow/PyTorch (framework-eager) for
//! every Table 1 workload, plus the §5.1 Transformer and BERT case-study
//! rows (memory-intensive time and kernel-call reduction).
//!
//! Paper reference: up to 3.35×, average 2.27× end-to-end; Transformer
//! mem-intensive 66.06 → 21.52 ms, kernel calls 42884 → 6186; BERT
//! mem-intensive 5.96 → 3.33 ms, kernels 198 → 97.
//!
//! Our numbers come from the T4 cost model over measured launch/byte
//! counts (see DESIGN.md §3): the *shape* — who wins and by roughly what
//! factor — is the reproduction target, not absolute milliseconds.

use disc::bench::{speedup, Table};
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::coordinator::serve_closed_loop;
use disc::runtime::metrics::RunMetrics;
use disc::sim::GpuModel;

const REQUESTS: usize = 20;
const SEED: u64 = 31;

fn run_mode(
    compiler: &DiscCompiler,
    w: &disc::workloads::Workload,
    mode: Mode,
) -> (RunMetrics, f64) {
    let module = disc::bridge::lower(&w.graph).expect("lower");
    let mut model = compiler.compile(module, &CompileOptions::mode(mode)).expect("compile");
    // Warm with the same stream: the measured pass is steady-state
    // (compilation is measured by the compile_overhead bench, not here).
    for inputs in w.request_stream(REQUESTS, SEED) {
        model.run(&inputs).expect("warmup");
    }
    let stream = w.request_stream(REQUESTS, SEED);
    let report = serve_closed_loop(&mut model, stream).expect("serve");
    (report.metrics.clone(), report.wall.as_secs_f64() * 1e3)
}

fn main() {
    let compiler = DiscCompiler::new().expect("pjrt device");
    let gpu = GpuModel::default();

    println!("=== Figure 3: speedup vs TensorFlow/PyTorch (T4 cost model) ===\n");
    let mut table = Table::new(&[
        "workload", "fw", "batch", "eager e2e(ms)", "disc e2e(ms)", "speedup",
        "mem eager(ms)", "mem disc(ms)", "mem speedup",
    ]);
    let mut speedups = Vec::new();
    let mut case_rows: Vec<(String, RunMetrics, RunMetrics)> = Vec::new();

    for w in disc::workloads::all() {
        let (em, _) = run_mode(&compiler, &w, Mode::Eager);
        let (dm, _) = run_mode(&compiler, &w, Mode::Disc);
        let eb = gpu.breakdown(&em);
        let db = gpu.breakdown(&dm);
        // Device-side comparison (comp + mem): host CPU time is measured on
        // this testbed's CPU executor and reported separately in Table 2.
        let e_dev = eb.comp_bound_ms + eb.mem_bound_ms;
        let d_dev = db.comp_bound_ms + db.mem_bound_ms;
        speedups.push(e_dev / d_dev);
        table.row(&[
            w.name.to_string(),
            w.framework.to_string(),
            w.batch.to_string(),
            format!("{e_dev:.3}"),
            format!("{d_dev:.3}"),
            speedup(e_dev, d_dev),
            format!("{:.3}", eb.mem_bound_ms),
            format!("{:.3}", db.mem_bound_ms),
            speedup(eb.mem_bound_ms, db.mem_bound_ms),
        ]);
        if w.name == "transformer" || w.name == "bert" {
            case_rows.push((w.name.to_string(), em, dm));
        }
    }
    table.print();
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    println!(
        "\naverage device speedup {avg:.2}x, max {max:.2}x \
         (paper: avg 2.27x, max 3.35x end-to-end on T4)"
    );

    println!("\n=== §5.1 case studies: kernel-call reduction ===\n");
    let mut cs = Table::new(&[
        "model", "eager mem-kernels", "disc mem-kernels", "reduction",
        "eager mem-bytes", "disc mem-bytes",
    ]);
    for (name, em, dm) in &case_rows {
        cs.row(&[
            name.clone(),
            em.mem_kernels.to_string(),
            dm.mem_kernels.to_string(),
            format!("{:.2}x", em.mem_kernels as f64 / dm.mem_kernels as f64),
            disc::util::fmt_bytes(em.mem_bytes as usize),
            disc::util::fmt_bytes(dm.mem_bytes as usize),
        ]);
    }
    cs.print();
    println!(
        "\n(paper: Transformer 42884 → 6186 kernel calls over its full run; \
         BERT 198 → 97 per inference)"
    );
}
