//! Traffic-adaptive re-bucketing under a Zipf-skewed length stream, with
//! the gates the CI smoke run (`DISC_BENCH_SMOKE=1`) enforces:
//!
//! * outputs are bit-exact between the static-NextPow2 model and the
//!   adaptive model across the epoch flip — re-bucketing moves launch
//!   geometry, never values;
//! * after the flip, the adaptive model's padded-element ratio is
//!   strictly below static NextPow2 on the same stream — the derived
//!   boundaries hug the observed traffic instead of doubling;
//! * the flip is zero-stall: the candidate bucket family is pre-compiled
//!   through the kernel store before the epoch swaps, so post-flip
//!   dispatches never block on a compile (`compile_stall == 0`);
//! * post-flip wall time stays within tolerance of the static model —
//!   the policy read is one atomic load per dispatch.
//!
//! Writes `BENCH_rebucket.json` at the repo root for the CI artifact.

use disc::bench::{zipf_lengths, Table};
use disc::codegen::BucketPolicy;
use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::runtime::tensor::Tensor;
use disc::util::json::{to_string_pretty, Value};
use disc::util::prng::Prng;
use std::time::{Duration, Instant};

const SEED: u64 = 0x5EED_2EB0;
const MAX_BUCKETS: usize = 6;

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

fn fresh(compiler: &DiscCompiler) -> CompiledModel {
    let w = disc::workloads::transformer::workload();
    let module = disc::bridge::lower(&w.graph).expect("lower");
    let mut opts = CompileOptions::mode(Mode::Disc);
    // Both models start from the same static base so the adaptive one's
    // post-flip win is attributable to the derived boundaries alone.
    opts.policy = Some(BucketPolicy::NextPow2);
    compiler.compile(module, &opts).expect("compile")
}

/// One pass over the request stream: outputs plus summed padding/stall
/// counters and total wall time.
struct Phase {
    outputs: Vec<Vec<Tensor>>,
    launch_elems: u64,
    padded_elems: u64,
    stall: Duration,
    wall: Duration,
}

impl Phase {
    fn padding_ratio(&self) -> f64 {
        if self.launch_elems == 0 {
            0.0
        } else {
            self.padded_elems as f64 / self.launch_elems as f64
        }
    }
}

fn run_phase(model: &mut CompiledModel, requests: &[Vec<Tensor>]) -> Phase {
    let mut outputs = Vec::new();
    let (mut launch, mut padded) = (0u64, 0u64);
    let mut stall = Duration::ZERO;
    let t0 = Instant::now();
    for r in requests {
        let out = model.run(r).expect("dispatch");
        launch += out.metrics.launch_elems;
        padded += out.metrics.padded_elems;
        stall += out.metrics.compile_stall;
        outputs.push(out.outputs);
    }
    Phase { outputs, launch_elems: launch, padded_elems: padded, stall, wall: t0.elapsed() }
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let n: usize = if smoke { 16 } else { 48 };
    // The range starts just past a power of two, so NextPow2 rounds the
    // (Zipf-dominant) short requests all the way up to 64 — the padding
    // regime adaptive boundaries are built to collapse.
    let (lo, hi) = (33usize, 96usize);
    let lengths = zipf_lengths(SEED, n, lo, hi, 1.1);
    let w = disc::workloads::transformer::workload();
    let mut rng = Prng::new(SEED ^ 1);
    let requests: Vec<Vec<Tensor>> =
        lengths.iter().map(|&l| (w.gen)(l, &mut rng)).collect();

    let compiler = DiscCompiler::new().expect("pjrt device");
    println!(
        "=== Traffic-adaptive re-bucketing: {n} Zipf requests over [{lo},{hi}], \
         seed={SEED:#x} ===\n"
    );

    // Static baseline: NextPow2 throughout. Warm (compiles + plan
    // records), settle (steady-state replays), then the measured pass.
    let mut st = fresh(&compiler);
    let _ = run_phase(&mut st, &requests);
    let _ = run_phase(&mut st, &requests);
    let st_b = run_phase(&mut st, &requests);

    // Adaptive: the same warm traffic feeds the extent histogram, then one
    // explicit re-derivation stands in for the background loop (same code
    // path, deterministic timing for a gated bench). The first post-flip
    // pass re-records plans under the new epoch; the measured pass is
    // steady-state, symmetric with the static baseline.
    let mut ad = fresh(&compiler);
    let _ = run_phase(&mut ad, &requests);
    let swapped = ad.rebucket_now(MAX_BUCKETS).expect("rebucket");
    assert!(swapped, "seed {SEED:#x}: warm traffic must produce a non-trivial policy");
    let flip = run_phase(&mut ad, &requests);
    let ad_b = run_phase(&mut ad, &requests);

    // Gate: bit-exact across the epoch flip, both immediately after it and
    // at steady state.
    assert_eq!(
        flip.outputs, st_b.outputs,
        "seed {SEED:#x}: outputs diverged on the first pass after the flip"
    );
    assert_eq!(
        ad_b.outputs, st_b.outputs,
        "seed {SEED:#x}: adaptive outputs diverged from static NextPow2 at steady state"
    );
    // Gate: strictly less padding on the same stream.
    assert!(
        ad_b.padding_ratio() < st_b.padding_ratio(),
        "seed {SEED:#x}: adaptive padding_ratio {:.4} must undercut static {:.4}",
        ad_b.padding_ratio(),
        st_b.padding_ratio()
    );
    // Gate: the swap pre-compiled the candidate family, so no dispatch
    // from the instant of the flip onward blocks on a compile.
    assert_eq!(
        flip.stall + ad_b.stall,
        Duration::ZERO,
        "seed {SEED:#x}: post-flip dispatches stalled on compilation"
    );
    // Wall-time tolerance, not a race — CI boxes are noisy at this scale.
    assert!(
        ad_b.wall <= st_b.wall.mul_f64(1.5) + Duration::from_millis(10),
        "seed {SEED:#x}: adaptive post-flip wall {:?} blew past static {:?}",
        ad_b.wall,
        st_b.wall
    );

    let mut t = Table::new(&["policy", "padding_ratio", "padded(K)", "stall", "wall"]);
    let mut rows: Vec<Value> = Vec::new();
    for (name, p) in [("static-pow2", &st_b), ("adaptive", &ad_b)] {
        t.row(&[
            name.to_string(),
            format!("{:.4}", p.padding_ratio()),
            format!("{:.1}", p.padded_elems as f64 / 1e3),
            format!("{:.2?}", p.stall),
            format!("{:.2?}", p.wall),
        ]);
        rows.push(obj(vec![
            ("policy", Value::Str(name.to_string())),
            ("padding_ratio", Value::Num(p.padding_ratio())),
            ("padded_elems", Value::Num(p.padded_elems as f64)),
            ("launch_elems", Value::Num(p.launch_elems as f64)),
            ("stall_ms", Value::Num(p.stall.as_secs_f64() * 1e3)),
            ("wall_ms", Value::Num(p.wall.as_secs_f64() * 1e3)),
        ]));
    }
    println!();
    t.print();
    println!(
        "\npadding_ratio {:.4} -> {:.4} ({:.0}% of static) across the epoch flip",
        st_b.padding_ratio(),
        ad_b.padding_ratio(),
        100.0 * ad_b.padding_ratio() / st_b.padding_ratio().max(f64::MIN_POSITIVE),
    );

    let doc = obj(vec![
        ("bench", Value::Str("rebucket".into())),
        ("requests", Value::Num(n as f64)),
        ("seed", Value::Str(format!("{SEED:#x}"))),
        ("max_buckets", Value::Num(MAX_BUCKETS as f64)),
        ("smoke", Value::Bool(smoke)),
        ("rows", Value::Arr(rows)),
    ]);
    let path = disc::bench::artifact_path("BENCH_rebucket.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote {}", path.display());
    println!(
        "\nReading guide: both models serve the identical Zipf stream from \
         the identical NextPow2 base; the adaptive one re-derives boundaries \
         from the warm phase's extent histogram and hot-swaps the epoch. \
         'padding_ratio' is padded/launched elements over the post-flip \
         phase — the padded-FLOP share the derived cuts reclaim."
    );
}
