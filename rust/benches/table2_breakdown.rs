//! Table 2: Transformer performance breakdown, Nimble-like VM vs DISC.
//!
//! Paper (ms): Nimble 66.58 / 56.09 / 65.83 / 188.5 vs
//!             DISC   59.68 / 21.52 / 24.08 / 105.28
//!             (comp-bound / mem-bound / CPU / E2E)
//!
//! Device columns come from the T4 cost model over measured counts; the
//! CPU column is *measured host time* on this testbed (that comparison —
//! interpreted VM flow vs compile-time-generated flow over identical
//! kernels — is the paper's architectural claim, and is hardware-real
//! here). Paper's CPU ratio: DISC = 36.6% of Nimble.

use disc::bench::Table;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::coordinator::serve_closed_loop;
use disc::sim::GpuModel;

const REQUESTS: usize = 30;
const SEED: u64 = 77;

fn main() {
    let compiler = DiscCompiler::new().expect("pjrt device");
    let gpu = GpuModel::default();
    let w = disc::workloads::transformer::workload();

    let mut rows = Vec::new();
    for (label, mode) in [("Nimble (VM)", Mode::VmNimble), ("DISC", Mode::Disc)] {
        let module = disc::bridge::lower(&w.graph).expect("lower");
        let mut model =
            compiler.compile(module, &CompileOptions::mode(mode)).expect("compile");
        // Warm with the SAME stream: the measured pass is all cache hits,
        // so host-time comparison is steady-state (compilation is measured
        // by compile_overhead).
        for inputs in w.request_stream(REQUESTS, SEED) {
            model.run(&inputs).expect("warmup");
        }
        let report =
            serve_closed_loop(&mut model, w.request_stream(REQUESTS, SEED)).expect("serve");
        let b = gpu.breakdown(&report.metrics);
        rows.push((label, b, report.metrics.clone()));
    }

    println!("=== Table 2: Transformer breakdown (per {REQUESTS}-request stream) ===\n");
    let mut t = Table::new(&["backend", "comp-bound(ms)", "mem-bound(ms)", "CPU(ms)", "E2E(ms)"]);
    for (label, b, _) in &rows {
        t.row(&[
            label.to_string(),
            format!("{:.3}", b.comp_bound_ms),
            format!("{:.3}", b.mem_bound_ms),
            format!("{:.3}", b.cpu_ms),
            format!("{:.3}", b.e2e_ms),
        ]);
    }
    t.print();

    let nimble_cpu = rows[0].1.cpu_ms;
    let disc_cpu = rows[1].1.cpu_ms;
    println!(
        "\nCPU time: DISC = {:.1}% of Nimble (paper: 36.6%) — the generated \
         runtime flow vs VM interpretation gap, measured on real host time.",
        100.0 * disc_cpu / nimble_cpu
    );
    let dm = &rows[1].2;
    println!(
        "DISC launch plans: {} hits / {} misses over the measured stream; \
         host<->device traffic h2d={} d2h={} (device-resident replay).",
        dm.plan_hits,
        dm.plan_misses,
        disc::util::fmt_bytes(dm.h2d_bytes as usize),
        disc::util::fmt_bytes(dm.d2h_bytes as usize)
    );
    println!(
        "DISC weight cache: {} hits / {} misses, {} resident — GEMM weights \
         upload once per program; every steady-state call serves them by \
         reference (the h2d column above excludes them entirely).",
        dm.weight_cache_hits,
        dm.weight_cache_misses,
        disc::util::fmt_bytes(dm.weight_resident_bytes as usize)
    );
    println!(
        "mem-bound: DISC = {:.2}x faster (paper: 2.61x) — constraint-driven \
         fusion scope.",
        rows[0].1.mem_bound_ms / rows[1].1.mem_bound_ms
    );
    println!("\npaper reference (ms): Nimble 66.58/56.09/65.83/188.5, DISC 59.68/21.52/24.08/105.28");
}
