//! Multi-worker serving scaling: throughput vs worker count under uniform
//! and bursty open-loop arrivals, with the shared-kernel-store counters
//! that prove the compile-once / stall-free claims:
//!
//! * throughput increases with workers on the saturated open-loop stream;
//! * kernel-store misses (actual compiles) stay FLAT across worker counts
//!   — each pattern×bucket compiles once per process no matter how many
//!   workers race it (single-flight dedup);
//! * on the steady-state replay pass, `compile_stall` is ~0: no request
//!   waits on the compiler once the store is warm;
//! * speculative neighbor-bucket warming moves first-touch compiles of a
//!   *growing* shape stream off the request path.
//!
//! `DISC_BENCH_SMOKE=1` shrinks the sweep for CI.

use disc::bench::Table;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::coordinator::{serve_open_loop, Arrival, ServeOptions};
use disc::util::json::{to_string_pretty, Value};
use std::time::Duration;

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let requests: usize = if smoke { 10 } else { 60 };
    let rate = 10_000.0; // saturating: exposes worker scaling, not arrival pacing
    let worker_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let seed = 91;

    let w = disc::workloads::transformer::workload();

    println!("=== Serving scaling: transformer, {requests}-request open-loop stream ===\n");
    let mut t = Table::new(&[
        "workers", "arrival", "throughput(r/s)", "p50", "p99", "queue-p99", "store-compiles",
        "dedup", "stall(ms)",
    ]);

    let mut uniform_compiles: Vec<u64> = Vec::new();
    let mut rows: Vec<Value> = Vec::new();
    for &workers in worker_counts {
        for (arrival, label) in
            [(Arrival::Uniform, "uniform"), (Arrival::Bursty { burst: 8 }, "burst=8")]
        {
            // Fresh compiler per config: the kernel store starts cold, so
            // the compiles column is directly comparable across rows.
            let compiler = DiscCompiler::new().expect("pjrt device");
            let module = disc::bridge::lower(&w.graph).expect("lower");
            let mut model =
                compiler.compile(module, &CompileOptions::mode(Mode::Disc)).expect("compile");
            let mut opts = ServeOptions::rate(rate).workers(workers);
            opts.arrival = arrival;
            let report =
                serve_open_loop(&mut model, w.request_stream(requests, seed), &opts)
                    .expect("serve");
            let snap = compiler.kernel_store().snapshot();
            if matches!(arrival, Arrival::Uniform) {
                uniform_compiles.push(snap.misses);
            }
            t.row(&[
                workers.to_string(),
                label.to_string(),
                format!("{:.0}", report.throughput_rps),
                format!("{:.2?}", report.p50),
                format!("{:.2?}", report.p99),
                format!("{:.2?}", report.queue_p99),
                snap.misses.to_string(),
                snap.dedup_hits.to_string(),
                format!("{:.2}", report.metrics.compile_stall.as_secs_f64() * 1e3),
            ]);
            rows.push(obj(vec![
                ("workers", Value::Num(workers as f64)),
                ("arrival", Value::Str(label.to_string())),
                ("throughput_rps", Value::Num(report.throughput_rps)),
                ("p50_ms", Value::Num(report.p50.as_secs_f64() * 1e3)),
                ("p99_ms", Value::Num(report.p99.as_secs_f64() * 1e3)),
                ("queue_p99_ms", Value::Num(report.queue_p99.as_secs_f64() * 1e3)),
                ("store_compiles", Value::Num(snap.misses as f64)),
                ("dedup_hits", Value::Num(snap.dedup_hits as f64)),
                (
                    "compile_stall_ms",
                    Value::Num(report.metrics.compile_stall.as_secs_f64() * 1e3),
                ),
            ]));
        }
    }
    t.print();
    // Persist the sweep for the CI workflow artifact (trend tracking).
    let doc = obj(vec![
        ("bench", Value::Str("serving_scaling".into())),
        ("workload", Value::Str("transformer".into())),
        ("requests", Value::Num(requests as f64)),
        ("smoke", Value::Bool(smoke)),
        ("rows", Value::Arr(rows)),
    ]);
    let path = disc::bench::artifact_path("BENCH_serving.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote {}", path.display());
    let flat = uniform_compiles.windows(2).all(|p| p[0] == p[1]);
    println!(
        "\nkernel-store compiles across worker counts: {:?} — {}",
        uniform_compiles,
        if flat { "FLAT (compile-once across workers holds)" } else { "NOT FLAT (regression!)" }
    );
    // This is deterministic (single-flight), so the CI smoke run gates on
    // it: more workers must never mean more compiles.
    assert!(flat, "kernel-store compiles grew with workers: {uniform_compiles:?}");

    // --- steady-state replay: zero compile stall ---------------------------
    // Workers=1 keeps the model's own executor (and its plan cache) across
    // the two passes; multi-worker serve calls fork fresh workers per call,
    // which is the sweep above, not a steady-state measurement.
    let compiler = DiscCompiler::new().expect("pjrt device");
    let module = disc::bridge::lower(&w.graph).expect("lower");
    let mut model =
        compiler.compile(module, &CompileOptions::mode(Mode::Disc)).expect("compile");
    let warm_opts = ServeOptions::rate(rate);
    let stream = w.request_stream(requests, seed);
    serve_open_loop(&mut model, stream.clone(), &warm_opts).expect("warm pass");
    let replay = serve_open_loop(&mut model, stream, &warm_opts).expect("replay pass");
    println!(
        "\nsteady-state replay: plan hits={} compile events={} stall={:.3}ms {}",
        replay.metrics.plan_hits,
        replay.metrics.compile_events,
        replay.metrics.compile_stall.as_secs_f64() * 1e3,
        if replay.metrics.compile_stall <= Duration::from_millis(1) {
            "— replay never waits on the compiler"
        } else {
            "(unexpected stall!)"
        }
    );
    // Deterministic: the warm pass resolved every key, so the replay pass
    // never touches the compile service. Gate on it in CI.
    assert_eq!(replay.metrics.compile_events, 0, "steady-state replay must not compile");
    assert_eq!(
        replay.metrics.compile_stall,
        Duration::ZERO,
        "steady-state replay must never wait on the compiler"
    );

    // --- speculative neighbor-bucket warming -------------------------------
    // A stream of ascending lengths that keeps crossing pow2/multiple-of-16
    // bucket boundaries: with warming on, the background pool compiles the
    // next bucket while the current one serves, so first-touch stall drops.
    let ascending: Vec<Vec<disc::runtime::tensor::Tensor>> = {
        let mut rng = disc::util::prng::Prng::new(7);
        let hi = if smoke { 40 } else { 96 };
        (w.seq_range.0..hi).step_by(3).map(|s| (w.gen)(s, &mut rng)).collect()
    };
    let mut stalls = Vec::new();
    for warm in [false, true] {
        let compiler = DiscCompiler::new().expect("pjrt device");
        let module = disc::bridge::lower(&w.graph).expect("lower");
        let mut copts = CompileOptions::mode(Mode::Disc);
        copts.runtime.speculative_warm = warm;
        let mut model = compiler.compile(module, &copts).expect("compile");
        // Modest rate: leaves wall-clock room between requests for the
        // background pool to finish the speculative compiles.
        let opts = ServeOptions::rate(if smoke { 2_000.0 } else { 300.0 });
        let report =
            serve_open_loop(&mut model, ascending.clone(), &opts).expect("serve ascending");
        let snap = compiler.kernel_store().snapshot();
        println!(
            "ascending-length stream, warm={warm}: stall={:.2}ms demand-compiles={} prefetched={}",
            report.metrics.compile_stall.as_secs_f64() * 1e3,
            snap.misses,
            snap.prefetches,
        );
        stalls.push(report.metrics.compile_stall);
    }
    if stalls[1] < stalls[0] {
        println!("speculative warming cut compile stall {:.2?} -> {:.2?}", stalls[0], stalls[1]);
    }
}
