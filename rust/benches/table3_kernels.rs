//! Table 3: Transformer kernel-number breakdown, Nimble-like VM vs DISC.
//!
//! Paper: Nimble 5232 comp / 8632 mem / 13924 total;
//!        DISC   4476 comp / 6186 mem / 10734 total.
//!
//! Kernel counts are deterministic functions of the fusion plan; this
//! bench counts them exactly over the same request stream for both
//! backends. Compute-intensive calls are identical by construction (both
//! use the §4.5 library); the memory-intensive gap comes from DISC's
//! constraint-widened fusion (Nimble plans with propagation only).

use disc::bench::Table;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::coordinator::serve_closed_loop;

const REQUESTS: usize = 30;
const SEED: u64 = 42;

fn main() {
    let compiler = DiscCompiler::new().expect("pjrt device");
    let w = disc::workloads::transformer::workload();

    println!("=== Table 3: Transformer kernel counts over {REQUESTS} requests ===\n");
    let mut t = Table::new(&["backend", "comp-bound", "mem-bound", "total", "fusion groups"]);
    let mut mem_counts = Vec::new();
    for (label, mode) in [("Nimble (VM)", Mode::VmNimble), ("DISC", Mode::Disc)] {
        let module = disc::bridge::lower(&w.graph).expect("lower");
        let mut model =
            compiler.compile(module, &CompileOptions::mode(mode)).expect("compile");
        for inputs in w.request_stream(REQUESTS, SEED) {
            model.run(&inputs).expect("warmup");
        }
        let report =
            serve_closed_loop(&mut model, w.request_stream(REQUESTS, SEED)).expect("serve");
        let m = &report.metrics;
        mem_counts.push(m.mem_kernels);
        t.row(&[
            label.to_string(),
            m.lib_calls.to_string(),
            m.mem_kernels.to_string(),
            m.total_kernels().to_string(),
            model.report.fusion_groups.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nmem-kernel ratio Nimble/DISC = {:.2} (paper: 8632/6186 = 1.40)",
        mem_counts[0] as f64 / mem_counts[1] as f64
    );
    println!("paper reference: Nimble 5232/8632/13924, DISC 4476/6186/10734");
}
