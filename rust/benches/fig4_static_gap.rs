//! Figure 4: performance gap between the dynamic compiler and static
//! optimization, on *static* inputs with fallback disabled.
//!
//! Paper: DISC reaches 74.5%–91.4% (avg 85%) of static-compiler
//! performance across three workloads; the gap is lost fusion/codegen
//! opportunity without full shape information.
//!
//! Here: the same workload graph is compiled twice — once with its
//! placeholders frozen to the input size (static pipeline: exact shapes,
//! no masks, no padding) and once fully dynamic (bucketed kernels +
//! runtime masking) — and both serve the identical fixed-size request.
//! Measured wall time per request on the real executor.

use disc::bench::{measure, Table};
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::util::prng::Prng;

fn main() {
    let compiler = DiscCompiler::new().expect("pjrt device");
    println!("=== Figure 4: dynamic vs static pipelines on static inputs ===\n");
    let mut t = Table::new(&["workload", "static ms/req", "dynamic ms/req", "dyn/static %"]);
    let mut ratios = Vec::new();

    // (workload, logical extent, placeholder extent for freezing)
    // Off-bucket extents (not multiples of 16) so the dynamic pipeline
    // pays its honest padding + masking cost.
    let cases: Vec<(disc::workloads::Workload, usize, usize)> = vec![
        (disc::workloads::transformer::workload(), 53, 53),
        (disc::workloads::bert::workload(), 53, 53),
        (
            disc::workloads::seq2seq::workload(),
            27,
            // seq2seq's dynamic placeholder is the flattened [B*S] id list.
            27 * disc::workloads::seq2seq::BATCH,
        ),
    ];

    for (w, seq, placeholder_extent) in cases {
        let mut rng = Prng::new(9);
        let inputs = (w.gen)(seq, &mut rng);

        // Static pipeline: frozen graph + exact-shape codegen.
        let frozen = disc::workloads::make_static(&w.graph, placeholder_extent);
        let m_static = disc::bridge::lower(&frozen).expect("lower static");
        let mut static_model = compiler
            .compile(m_static, &CompileOptions::mode(Mode::Static))
            .expect("compile static");

        // Dynamic pipeline: original graph, fallback disabled (Mode::Disc
        // always takes the dynamic pipeline).
        let m_dyn = disc::bridge::lower(&w.graph).expect("lower dynamic");
        let mut dyn_model =
            compiler.compile(m_dyn, &CompileOptions::mode(Mode::Disc)).expect("compile dynamic");

        // Interleaved A/B rounds with a full joint warmup; per-model
        // minimum-of-medians defeats process-level noise (thread-pool
        // spin-up, page-cache effects) that otherwise penalizes whichever
        // model is measured first.
        let ins1 = inputs.clone();
        let ins2 = inputs.clone();
        for _ in 0..8 {
            static_model.run(&ins1).expect("static warmup");
            dyn_model.run(&ins2).expect("dynamic warmup");
        }
        let mut best_static = f64::INFINITY;
        let mut best_dyn = f64::INFINITY;
        for _ in 0..4 {
            let ms = measure(w.name, 0, 8, || {
                static_model.run(&ins1).expect("static run");
            });
            let md = measure(w.name, 0, 8, || {
                dyn_model.run(&ins2).expect("dynamic run");
            });
            best_static = best_static.min(ms.median_ms());
            best_dyn = best_dyn.min(md.median_ms());
        }
        let ms_ms = best_static;
        let md_ms = best_dyn;
        let ratio = 100.0 * ms_ms / md_ms;
        ratios.push(ratio);
        t.row(&[
            w.name.to_string(),
            format!("{ms_ms:.3}"),
            format!("{md_ms:.3}"),
            format!("{ratio:.1}%"),
        ]);
    }
    t.print();
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    println!(
        "\naverage: dynamic reaches {avg:.1}% of static performance \
         (paper: 85% average, range 74.5%–91.4%)"
    );
}
