//! Multi-tenant serving isolation: the SLO-bulkhead claims, measured.
//!
//! * **Flood isolation** — a latency-bound transformer tenant keeps its
//!   p99 within 1.5× of its solo p99 while throughput-bound BERT and TTS
//!   tenants flood the shared worker pool (weighted-fair dispatch +
//!   per-tenant admission queues are what hold the line).
//! * **Fault isolation** — an armed worker-panic storm against one tenant
//!   trips *its* circuit breaker (quarantine + probing re-admission) while
//!   the healthy tenants complete everything with zero sheds, zero
//!   demotions, zero restarts.
//! * **Zero-lost accounting** — `completed + shed + missed == offered`
//!   holds per tenant in every configuration (reconciled inside
//!   `serve_mix`, spot-checked here).
//!
//! `DISC_BENCH_SMOKE=1` shrinks the streams for CI. Writes
//! `BENCH_multitenant.json` at the repo root (`bench::artifact_path`) for
//! the CI bench artifact.

use disc::bench::Table;
use disc::coordinator::tenants::{serve_mix, MixOptions, TenantReport, TenantSpec};
use disc::runtime::faults::{FaultPlan, FaultSite};
use disc::util::json::{to_string_pretty, Value};
use std::sync::Arc;
use std::time::Duration;

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

fn tenant_row(label: &str, t: &TenantReport) -> Value {
    let m = &t.report.metrics;
    obj(vec![
        ("run", Value::Str(label.to_string())),
        ("tenant", Value::Str(t.name.clone())),
        ("slo", Value::Str(t.slo.as_str().to_string())),
        ("offered", Value::Num(t.offered as f64)),
        ("completed", Value::Num(t.report.completed as f64)),
        ("p50_ms", Value::Num(t.report.p50.as_secs_f64() * 1e3)),
        ("p99_ms", Value::Num(t.report.p99.as_secs_f64() * 1e3)),
        ("throughput_rps", Value::Num(t.report.throughput_rps)),
        ("shed", Value::Num(m.shed_requests as f64)),
        ("deadline_misses", Value::Num(m.deadline_misses as f64)),
        ("demotions", Value::Num(m.demotions as f64)),
        ("worker_restarts", Value::Num(m.worker_restarts as f64)),
        ("breaker_trips", Value::Num(t.breaker_trips as f64)),
        ("probes", Value::Num(t.probes as f64)),
        ("quarantined", Value::Num(m.quarantined as f64)),
    ])
}

fn assert_zero_lost(t: &TenantReport) {
    let m = &t.report.metrics;
    assert_eq!(
        t.report.completed as u64 + m.shed_requests + m.deadline_misses,
        t.offered as u64,
        "tenant {} lost requests",
        t.name
    );
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let lat_requests: usize = if smoke { 24 } else { 80 };
    let flood_requests: usize = if smoke { 40 } else { 160 };
    let workers = 2;

    let latency_tenant = || {
        TenantSpec::latency("lat-transformer", "transformer")
            .requests(lat_requests)
            .rate(300.0)
            .seed(31)
    };

    println!("=== Multi-tenant serving: latency tenant vs flooding neighbors ===\n");

    // --- flood isolation: solo baseline, then the mixed pool ---------------
    // The p99 ratio is a timing gate on a shared machine, so it gets the
    // usual retry allowance; the accounting gates are deterministic and
    // asserted on every attempt.
    let mut rows: Vec<Value> = Vec::new();
    let mut attempt = 0;
    let (solo_p99, mixed) = loop {
        attempt += 1;
        let solo = serve_mix(vec![latency_tenant()], &MixOptions::new().workers(workers))
            .expect("solo serve");
        let solo_p99 = solo.tenants[0].report.p99;
        assert_zero_lost(&solo.tenants[0]);

        let specs = vec![
            latency_tenant(),
            TenantSpec::throughput("thr-bert", "bert")
                .requests(flood_requests)
                .rate(2_000.0)
                .seed(32),
            TenantSpec::throughput("thr-tts", "tts")
                .requests(flood_requests)
                .rate(2_000.0)
                .seed(33)
                .bursty(16),
        ];
        let mixed =
            serve_mix(specs, &MixOptions::new().workers(workers).batch(4)).expect("mixed serve");
        for t in &mixed.tenants {
            assert_zero_lost(t);
            assert_eq!(t.breaker_trips, 0, "fault-free mix must not trip breakers");
        }

        let mixed_p99 = mixed.tenants[0].report.p99;
        // 500µs of absolute grace keeps sub-millisecond solo baselines from
        // turning scheduler jitter into a flaky ratio.
        let ok = mixed_p99 <= solo_p99.mul_f64(1.5) + Duration::from_micros(500);
        println!(
            "attempt {attempt}: latency-tenant p99 solo={solo_p99:.2?} mixed={mixed_p99:.2?} ({})",
            if ok { "within 1.5x" } else { "OVER 1.5x" }
        );
        if ok || attempt >= 3 {
            assert!(
                ok,
                "latency tenant p99 {mixed_p99:.2?} exceeded 1.5x solo {solo_p99:.2?} \
                 after {attempt} attempts"
            );
            rows.push(tenant_row("solo", &solo.tenants[0]));
            break (solo_p99, mixed);
        }
    };

    let mut table = Table::new(&[
        "tenant", "slo", "completed", "p50", "p99", "throughput(r/s)", "shed", "trips",
    ]);
    for t in &mixed.tenants {
        table.row(&[
            t.name.clone(),
            t.slo.as_str().to_string(),
            format!("{}/{}", t.report.completed, t.offered),
            format!("{:.2?}", t.report.p50),
            format!("{:.2?}", t.report.p99),
            format!("{:.0}", t.report.throughput_rps),
            t.report.metrics.shed_requests.to_string(),
            t.breaker_trips.to_string(),
        ]);
        rows.push(tenant_row("mixed", t));
    }
    table.print();
    println!(
        "\nlatency-tenant p99: solo={solo_p99:.2?} mixed={:.2?} (gate: <=1.5x)",
        mixed.tenants[0].report.p99
    );

    // --- fault isolation: a panic storm against one tenant -----------------
    // Deterministic (the fault schedule fires on the first consults), so
    // every gate here is hard.
    println!("\n=== Fault storm against one tenant (breaker + quarantine) ===\n");
    let plan = Arc::new(FaultPlan::parse("seed=17,panic=1000:4").expect("fault spec"));
    let specs = vec![
        TenantSpec::latency("healthy", "tts").requests(lat_requests).rate(500.0).seed(41),
        TenantSpec::throughput("faulty", "tts")
            .requests(flood_requests)
            .rate(900.0)
            .seed(42)
            .fault_target(),
    ];
    let storm = serve_mix(
        specs,
        &MixOptions::new().workers(workers).batch(2).faults(plan.clone()).breaker(2, 2),
    )
    .expect("storm serve");
    let healthy = &storm.tenants[0];
    let faulty = &storm.tenants[1];
    for t in &storm.tenants {
        assert_zero_lost(t);
        rows.push(tenant_row("storm", t));
    }
    println!(
        "faulty tenant: restarts={} breaker_trips={} probes={} quarantined={} (panics fired={})",
        faulty.report.metrics.worker_restarts,
        faulty.breaker_trips,
        faulty.probes,
        faulty.report.metrics.quarantined,
        plan.fired(FaultSite::WorkerPanic),
    );
    println!(
        "healthy tenant: completed {}/{} shed={} demotions={} restarts={}",
        healthy.report.completed,
        healthy.offered,
        healthy.report.metrics.shed_requests,
        healthy.report.metrics.demotions,
        healthy.report.metrics.worker_restarts,
    );
    assert!(faulty.breaker_trips >= 1, "the storm must trip the faulty tenant's breaker");
    assert!(faulty.report.metrics.quarantined > 0, "open breaker must quarantine");
    assert_eq!(healthy.report.completed, healthy.offered, "healthy tenant must finish");
    assert_eq!(healthy.report.metrics.shed_requests, 0, "healthy tenant must shed nothing");
    assert_eq!(healthy.report.metrics.demotions, 0, "healthy tenant must never demote");
    assert_eq!(healthy.report.metrics.worker_restarts, 0);
    assert_eq!(healthy.breaker_trips, 0);

    // Persist for the CI workflow artifact (trend tracking).
    let doc = obj(vec![
        ("bench", Value::Str("multitenant".into())),
        ("smoke", Value::Bool(smoke)),
        ("workers", Value::Num(workers as f64)),
        ("solo_p99_ms", Value::Num(solo_p99.as_secs_f64() * 1e3)),
        (
            "mixed_p99_ms",
            Value::Num(mixed.tenants[0].report.p99.as_secs_f64() * 1e3),
        ),
        ("rows", Value::Arr(rows)),
    ]);
    let path = disc::bench::artifact_path("BENCH_multitenant.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote {}", path.display());
}
