//! Cross-request batching: batch on/off × workers over a bursty open-loop
//! stream (transformer), with the correctness and coalescing gates the CI
//! smoke run (`DISC_BENCH_SMOKE=1`) enforces:
//!
//! * every served output is **bit-identical** to an unbatched
//!   single-worker run of the same stream;
//! * with batching on, a bursty flood coalesces: `batch_occupancy > 1`
//!   and `batch_launches < requests`;
//! * batching launches strictly fewer kernels than serving the same
//!   stream solo.
//!
//! Writes `BENCH_batching.json` next to the manifest for the CI bench
//! artifact (trend tracking across runs).

use disc::bench::Table;
use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::coordinator::{serve_open_loop, ServeOptions, ServeReport};
use disc::runtime::tensor::Tensor;
use disc::util::json::{to_string_pretty, Value};

fn fresh_model() -> CompiledModel {
    let w = disc::workloads::transformer::workload();
    let compiler = DiscCompiler::new().expect("pjrt device");
    let module = disc::bridge::lower(&w.graph).expect("lower");
    compiler.compile(module, &CompileOptions::mode(Mode::Disc)).expect("compile")
}

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

/// Serve the stream under the given batching/worker config, bursty at a
/// flooding rate so the queue fills while dispatches run.
fn serve(stream: &[Vec<Tensor>], max_batch: usize, workers: usize) -> ServeReport {
    let mut model = fresh_model();
    let opts = ServeOptions::rate(1_000_000.0)
        .workers(workers)
        .bursty(stream.len())
        .batch(max_batch)
        .batch_window_us(if max_batch > 1 { 200 } else { 0 })
        .keep_outputs();
    serve_open_loop(&mut model, stream.to_vec(), &opts).expect("serve")
}

fn check_outputs(report: &ServeReport, reference: &[Vec<Tensor>], label: &str) {
    assert_eq!(report.outputs.len(), reference.len(), "{label}: missing outputs");
    for (id, got) in &report.outputs {
        let want = &reference[*id as usize];
        assert_eq!(
            got, want,
            "{label}: request {id} diverged from the unbatched single-worker run"
        );
    }
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let requests: usize = if smoke { 12 } else { 48 };
    let seed = 77;
    let w = disc::workloads::transformer::workload();
    let stream = w.request_stream(requests, seed);

    // Reference: unbatched direct runs on a fresh model (the interpreter /
    // replay tiers, no coordinator, no batching).
    let mut reference_model = fresh_model();
    let reference: Vec<Vec<Tensor>> =
        stream.iter().map(|r| reference_model.run(r).expect("reference run").outputs).collect();

    println!("=== Cross-request batching: transformer, {requests}-request bursty flood ===\n");
    let mut t = Table::new(&[
        "batch", "workers", "throughput(r/s)", "dispatches", "occupancy", "kernels",
        "pad-waste(KiB)", "p99",
    ]);
    let mut rows: Vec<Value> = Vec::new();

    let configs: &[(usize, usize)] =
        if smoke { &[(1, 1), (4, 1), (4, 2)] } else { &[(1, 1), (8, 1), (1, 2), (8, 2)] };
    let mut solo_kernels: Option<u64> = None;
    let mut batched_1w: Option<ServeReport> = None;
    for &(max_batch, workers) in configs {
        // Batch formation depends on queue depth at dispatch time; a flood
        // makes coalescing overwhelmingly likely, but the gate below
        // retries a couple of times before declaring a regression.
        let mut report = serve(&stream, max_batch, workers);
        if max_batch > 1 {
            for _ in 0..2 {
                if report.batch_occupancy > 1.0 {
                    break;
                }
                report = serve(&stream, max_batch, workers);
            }
        }
        check_outputs(&report, &reference, &format!("batch={max_batch} workers={workers}"));
        t.row(&[
            max_batch.to_string(),
            workers.to_string(),
            format!("{:.0}", report.throughput_rps),
            report.batch_launches.to_string(),
            format!("{:.2}", report.batch_occupancy),
            report.metrics.total_kernels().to_string(),
            format!("{:.1}", report.metrics.batch_padding_bytes as f64 / 1024.0),
            format!("{:.2?}", report.p99),
        ]);
        rows.push(obj(vec![
            ("batch", Value::Num(max_batch as f64)),
            ("workers", Value::Num(workers as f64)),
            ("requests", Value::Num(report.completed as f64)),
            ("throughput_rps", Value::Num(report.throughput_rps)),
            ("dispatches", Value::Num(report.batch_launches as f64)),
            ("occupancy", Value::Num(report.batch_occupancy)),
            ("batched_requests", Value::Num(report.batched_requests as f64)),
            ("total_kernels", Value::Num(report.metrics.total_kernels() as f64)),
            ("batch_padding_bytes", Value::Num(report.metrics.batch_padding_bytes as f64)),
            ("p99_ms", Value::Num(report.p99.as_secs_f64() * 1e3)),
        ]));
        if max_batch == 1 && workers == 1 {
            solo_kernels = Some(report.metrics.total_kernels());
        }
        if max_batch > 1 && workers == 1 && batched_1w.is_none() {
            batched_1w = Some(report);
        }
    }
    t.print();

    // --- gates (deterministic given the flood + retries above) ------------
    let batched = batched_1w.expect("sweep includes a single-worker batched config");
    println!(
        "\nbatching on (1 worker): {} requests in {} dispatches (occupancy {:.2}), \
         kernels {} vs {} solo",
        batched.completed,
        batched.batch_launches,
        batched.batch_occupancy,
        batched.metrics.total_kernels(),
        solo_kernels.unwrap(),
    );
    assert!(
        batched.batch_occupancy > 1.0,
        "bursty flood failed to coalesce: occupancy {:.2}",
        batched.batch_occupancy
    );
    assert!(
        batched.batch_launches < requests,
        "batching must dispatch fewer times than the request count ({} vs {requests})",
        batched.batch_launches
    );
    assert!(
        batched.metrics.total_kernels() < solo_kernels.unwrap(),
        "batching must launch fewer kernels ({} vs {} solo)",
        batched.metrics.total_kernels(),
        solo_kernels.unwrap()
    );

    let doc = obj(vec![
        ("bench", Value::Str("batching".into())),
        ("workload", Value::Str("transformer".into())),
        ("requests", Value::Num(requests as f64)),
        ("smoke", Value::Bool(smoke)),
        ("rows", Value::Arr(rows)),
    ]);
    std::fs::write("BENCH_batching.json", to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote BENCH_batching.json");
}
