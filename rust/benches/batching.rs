//! Cross-request batching: batch on/off × workers × batch-plan-cache
//! on/off over a bursty open-loop stream (transformer), with the
//! correctness and coalescing gates the CI smoke run
//! (`DISC_BENCH_SMOKE=1`) enforces:
//!
//! * every served output is **bit-identical** to an unbatched
//!   single-worker run of the same stream;
//! * with batching on, a bursty flood coalesces: `batch_occupancy > 1`
//!   and `batch_launches < requests`;
//! * batching launches strictly fewer kernels than serving the same
//!   stream solo;
//! * repeat same-shape groups **replay** a recorded batch plan
//!   (`batch_plan_hits > 0`) and spend less wall time per dispatch than
//!   the plan-cache-off interpret tier (measured on a deterministic
//!   repeat-group sweep, not the timing-sensitive open loop).
//!
//! Writes `BENCH_batching.json` at the repo root (`bench::artifact_path`)
//! for the CI bench artifact (trend tracking across runs).

use disc::bench::Table;
use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::coordinator::{serve_open_loop, ServeOptions, ServeReport};
use disc::runtime::tensor::Tensor;
use disc::util::json::{to_string_pretty, Value};
use std::time::{Duration, Instant};

fn fresh_model_opts(plan_cache: bool) -> CompiledModel {
    let w = disc::workloads::transformer::workload();
    let compiler = DiscCompiler::new().expect("pjrt device");
    let module = disc::bridge::lower(&w.graph).expect("lower");
    let mut opts = CompileOptions::mode(Mode::Disc);
    opts.plan_cache = plan_cache;
    compiler.compile(module, &opts).expect("compile")
}

fn fresh_model() -> CompiledModel {
    fresh_model_opts(true)
}

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

/// Serve the stream under the given batching/worker/plan-cache config,
/// bursty at a flooding rate so the queue fills while dispatches run.
fn serve(
    stream: &[Vec<Tensor>],
    max_batch: usize,
    workers: usize,
    plan_cache: bool,
) -> ServeReport {
    let mut model = fresh_model_opts(plan_cache);
    let opts = ServeOptions::rate(1_000_000.0)
        .workers(workers)
        .bursty(stream.len())
        .batch(max_batch)
        .batch_window_us(if max_batch > 1 { 200 } else { 0 })
        .keep_outputs();
    serve_open_loop(&mut model, stream.to_vec(), &opts).expect("serve")
}

/// Dispatch the SAME group shape `rounds` times through `run_batch` and
/// return the median per-dispatch wall time plus the final plan counters
/// — the deterministic measurement behind the replay gate (open-loop
/// group formation depends on queue depth; this does not).
fn repeat_group_sweep(plan_cache: bool, rounds: usize) -> (Duration, u64, u64) {
    let w = disc::workloads::transformer::workload();
    let mut model = fresh_model_opts(plan_cache);
    let mut rng = disc::util::prng::Prng::new(101);
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut times: Vec<Duration> = Vec::with_capacity(rounds);
    for round in 0..rounds {
        let group: Vec<Vec<Tensor>> =
            [6usize, 9, 12].iter().map(|&s| (w.gen)(s, &mut rng)).collect();
        let t0 = Instant::now();
        let out = model.run_batch(&group).expect("batched dispatch");
        let dt = t0.elapsed();
        assert_eq!(out.metrics.batched_launches, 1, "group must stack");
        hits += out.metrics.batch_plan_hits;
        misses += out.metrics.batch_plan_misses;
        // Skip the cold round: it pays kernel compilation either way (and
        // plan recording on the cached config).
        if round > 0 {
            times.push(dt);
        }
    }
    times.sort_unstable();
    (times[times.len() / 2], hits, misses)
}

fn check_outputs(report: &ServeReport, reference: &[Vec<Tensor>], label: &str) {
    assert_eq!(report.outputs.len(), reference.len(), "{label}: missing outputs");
    for (id, got) in &report.outputs {
        let want = &reference[*id as usize];
        assert_eq!(
            got, want,
            "{label}: request {id} diverged from the unbatched single-worker run"
        );
    }
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let requests: usize = if smoke { 12 } else { 48 };
    let seed = 77;
    let w = disc::workloads::transformer::workload();
    let stream = w.request_stream(requests, seed);

    // Reference: unbatched direct runs on a fresh model (the interpreter /
    // replay tiers, no coordinator, no batching).
    let mut reference_model = fresh_model();
    let reference: Vec<Vec<Tensor>> =
        stream.iter().map(|r| reference_model.run(r).expect("reference run").outputs).collect();

    println!("=== Cross-request batching: transformer, {requests}-request bursty flood ===\n");
    let mut t = Table::new(&[
        "batch", "workers", "plans", "throughput(r/s)", "dispatches", "occupancy", "kernels",
        "plan h/m", "pad-waste(KiB)", "p99",
    ]);
    let mut rows: Vec<Value> = Vec::new();

    // (max_batch, workers, batch-plan cache)
    let configs: &[(usize, usize, bool)] = if smoke {
        &[(1, 1, true), (4, 1, false), (4, 1, true), (4, 2, true)]
    } else {
        &[(1, 1, true), (8, 1, false), (8, 1, true), (1, 2, true), (8, 2, true)]
    };
    let mut solo_kernels: Option<u64> = None;
    let mut batched_1w: Option<ServeReport> = None;
    for &(max_batch, workers, plan_cache) in configs {
        // Batch formation depends on queue depth at dispatch time; a flood
        // makes coalescing overwhelmingly likely, but the gate below
        // retries a couple of times before declaring a regression.
        let mut report = serve(&stream, max_batch, workers, plan_cache);
        if max_batch > 1 {
            for _ in 0..2 {
                if report.batch_occupancy > 1.0 {
                    break;
                }
                report = serve(&stream, max_batch, workers, plan_cache);
            }
        }
        check_outputs(
            &report,
            &reference,
            &format!("batch={max_batch} workers={workers} plans={plan_cache}"),
        );
        t.row(&[
            max_batch.to_string(),
            workers.to_string(),
            if plan_cache { "on" } else { "off" }.to_string(),
            format!("{:.0}", report.throughput_rps),
            report.batch_launches.to_string(),
            format!("{:.2}", report.batch_occupancy),
            report.metrics.total_kernels().to_string(),
            format!("{}/{}", report.metrics.batch_plan_hits, report.metrics.batch_plan_misses),
            format!("{:.1}", report.metrics.batch_padding_bytes as f64 / 1024.0),
            format!("{:.2?}", report.p99),
        ]);
        rows.push(obj(vec![
            ("batch", Value::Num(max_batch as f64)),
            ("workers", Value::Num(workers as f64)),
            ("plan_cache", Value::Bool(plan_cache)),
            ("requests", Value::Num(report.completed as f64)),
            ("throughput_rps", Value::Num(report.throughput_rps)),
            ("dispatches", Value::Num(report.batch_launches as f64)),
            ("occupancy", Value::Num(report.batch_occupancy)),
            ("batched_requests", Value::Num(report.batched_requests as f64)),
            ("total_kernels", Value::Num(report.metrics.total_kernels() as f64)),
            ("batch_plan_hits", Value::Num(report.metrics.batch_plan_hits as f64)),
            ("batch_plan_misses", Value::Num(report.metrics.batch_plan_misses as f64)),
            (
                "batch_dev_resident_bytes",
                Value::Num(report.metrics.batch_dev_resident_bytes as f64),
            ),
            ("batch_padding_bytes", Value::Num(report.metrics.batch_padding_bytes as f64)),
            ("p99_ms", Value::Num(report.p99.as_secs_f64() * 1e3)),
        ]));
        if max_batch == 1 && workers == 1 {
            solo_kernels = Some(report.metrics.total_kernels());
        }
        if max_batch > 1 && workers == 1 && plan_cache && batched_1w.is_none() {
            batched_1w = Some(report);
        }
    }
    t.print();

    // --- gates (deterministic given the flood + retries above) ------------
    let batched = batched_1w.expect("sweep includes a single-worker batched config");
    println!(
        "\nbatching on (1 worker): {} requests in {} dispatches (occupancy {:.2}), \
         kernels {} vs {} solo",
        batched.completed,
        batched.batch_launches,
        batched.batch_occupancy,
        batched.metrics.total_kernels(),
        solo_kernels.unwrap(),
    );
    assert!(
        batched.batch_occupancy > 1.0,
        "bursty flood failed to coalesce: occupancy {:.2}",
        batched.batch_occupancy
    );
    assert!(
        batched.batch_launches < requests,
        "batching must dispatch fewer times than the request count ({} vs {requests})",
        batched.batch_launches
    );
    assert!(
        batched.metrics.total_kernels() < solo_kernels.unwrap(),
        "batching must launch fewer kernels ({} vs {} solo)",
        batched.metrics.total_kernels(),
        solo_kernels.unwrap()
    );

    // --- batched plan replay: deterministic repeat-group sweep ------------
    // The same [6, 9, 12] group dispatched `rounds` times, plan cache on
    // vs off. The cached config must replay (hits = rounds - 1) and beat
    // the interpret tier's median per-dispatch wall time; wall comparisons
    // are noisy on shared CI runners, so the gate retries before failing.
    let rounds = if smoke { 10 } else { 30 };
    let mut replay_row = None;
    for attempt in 0..3 {
        let (t_off, hits_off, _) = repeat_group_sweep(false, rounds);
        let (t_on, hits_on, misses_on) = repeat_group_sweep(true, rounds);
        assert_eq!(hits_off, 0, "plan cache off must never replay");
        assert_eq!(misses_on, 1, "one record on first sight of the group shape");
        assert_eq!(hits_on as usize, rounds - 1, "every repeat must replay");
        println!(
            "\nrepeat-group sweep ({rounds} rounds): interpret {t_off:.2?}/dispatch vs \
             replay {t_on:.2?}/dispatch (attempt {attempt})"
        );
        if t_on < t_off {
            replay_row = Some((t_off, t_on, hits_on));
            break;
        }
    }
    let (t_off, t_on, replay_hits) =
        replay_row.expect("batched replay failed to beat the interpret tier in 3 attempts");
    assert!(replay_hits > 0, "replay gate requires batch_plan_hits > 0");

    let doc = obj(vec![
        ("bench", Value::Str("batching".into())),
        ("workload", Value::Str("transformer".into())),
        ("requests", Value::Num(requests as f64)),
        ("smoke", Value::Bool(smoke)),
        ("rows", Value::Arr(rows)),
        (
            "replay",
            obj(vec![
                ("rounds", Value::Num(rounds as f64)),
                ("interpret_ms_per_dispatch", Value::Num(t_off.as_secs_f64() * 1e3)),
                ("replay_ms_per_dispatch", Value::Num(t_on.as_secs_f64() * 1e3)),
                ("batch_plan_hits", Value::Num(replay_hits as f64)),
            ]),
        ),
    ]);
    let path = disc::bench::artifact_path("BENCH_batching.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote {}", path.display());
}
