//! Symbolic memory planning: repeat-binding replay arena peaks, planner on
//! vs planner off, with the gates the CI smoke run (`DISC_BENCH_SMOKE=1`)
//! enforces:
//!
//! * outputs are bit-exact between the two configurations — the planner
//!   moves buffers, never bytes;
//! * the planner-on replay arena footprint (`device_resident_bytes`, and
//!   `batch_dev_resident_bytes` for stacked dispatches) is strictly below
//!   the planner-off per-buffer footprint on transformer and BERT: one
//!   planned extent with slot sharing beats a cached per-size free list;
//! * planner-on wall time stays within tolerance of planner-off — the plan
//!   is computed at compile time, so replays pay one arena acquire instead
//!   of one per buffer.
//!
//! Writes `BENCH_memplan.json` at the repo root for the CI artifact.

use disc::bench::Table;
use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::runtime::tensor::Tensor;
use disc::util::json::{to_string_pretty, Value};
use disc::util::prng::Prng;
use disc::workloads::Workload;
use std::time::{Duration, Instant};

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

fn fresh(compiler: &DiscCompiler, w: &Workload, planner: bool) -> CompiledModel {
    let module = disc::bridge::lower(&w.graph).expect("lower");
    let mut opts = CompileOptions::mode(Mode::Disc);
    opts.runtime.memory_plan = planner;
    compiler.compile(module, &opts).expect("compile")
}

fn median(times: &mut [Duration]) -> Duration {
    times.sort_unstable();
    times[times.len() / 2]
}

/// One configuration's repeat-binding replay sweep: warm the binding so the
/// plan records, then replay it `rounds` times with fresh request contents.
struct Sweep {
    outputs: Vec<Vec<Tensor>>,
    peak_bytes: u64,
    planned_peak: u64,
    reuse_bytes: u64,
    median: Duration,
}

fn solo_sweep(model: &mut CompiledModel, requests: &[Vec<Tensor>]) -> Sweep {
    // First request records the plan (and pays interpretation); run it
    // twice so the timed rounds below are all steady-state replays.
    model.run(&requests[0]).expect("record run");
    model.run(&requests[0]).expect("first replay");
    let mut outputs = Vec::new();
    let mut times = Vec::new();
    let (mut peak, mut planned, mut reuse) = (0u64, 0u64, 0u64);
    for r in requests {
        let t0 = Instant::now();
        let out = model.run(r).expect("replay");
        times.push(t0.elapsed());
        peak = peak.max(out.metrics.device_resident_bytes);
        planned = planned.max(out.metrics.planned_peak_bytes);
        reuse += out.metrics.mem_plan_reuse_bytes;
        outputs.push(out.outputs);
    }
    let median = median(&mut times);
    Sweep {
        outputs,
        peak_bytes: peak,
        planned_peak: planned,
        reuse_bytes: reuse,
        median,
    }
}

fn batch_sweep(model: &mut CompiledModel, rounds: &[Vec<Vec<Tensor>>]) -> Sweep {
    model.run_batch(&rounds[0]).expect("record dispatch");
    model.run_batch(&rounds[0]).expect("first replay");
    let mut outputs = Vec::new();
    let mut times = Vec::new();
    let (mut peak, mut planned, mut reuse) = (0u64, 0u64, 0u64);
    for reqs in rounds {
        let t0 = Instant::now();
        let out = model.run_batch(reqs).expect("batch replay");
        times.push(t0.elapsed());
        peak = peak.max(out.metrics.batch_dev_resident_bytes);
        planned = planned.max(out.metrics.planned_peak_bytes);
        reuse += out.metrics.mem_plan_reuse_bytes;
        outputs.extend(out.outputs.iter().cloned());
    }
    let median = median(&mut times);
    Sweep {
        outputs,
        peak_bytes: peak,
        planned_peak: planned,
        reuse_bytes: reuse,
        median,
    }
}

fn gate(name: &str, on: &Sweep, off: &Sweep, rows: &mut Vec<Value>, t: &mut Table) {
    assert_eq!(
        on.outputs, off.outputs,
        "{name}: planner-on outputs diverged from planner-off (must be bit-exact)"
    );
    assert!(
        on.planned_peak > 0,
        "{name}: planner-on replays carried no memory plan (instantiate declined?)"
    );
    assert!(
        on.peak_bytes < off.peak_bytes,
        "{name}: planned extent {} must undercut the per-buffer footprint {}",
        on.peak_bytes,
        off.peak_bytes
    );
    // Wall-time tolerance, not a race: the plan costs one arena acquire per
    // replay. Generous bound — CI boxes are noisy at these time scales.
    assert!(
        on.median <= off.median.mul_f64(1.5) + Duration::from_millis(10),
        "{name}: planner-on median {:?} blew past planner-off {:?}",
        on.median,
        off.median
    );
    for (planner, s) in [("on", on), ("off", off)] {
        t.row(&[
            name.to_string(),
            planner.to_string(),
            format!("{:.1}", s.peak_bytes as f64 / 1024.0),
            format!("{:.1}", s.planned_peak as f64 / 1024.0),
            format!("{:.1}", s.reuse_bytes as f64 / 1024.0),
            format!("{:.2?}", s.median),
        ]);
        rows.push(obj(vec![
            ("case", Value::Str(name.to_string())),
            ("planner", Value::Str(planner.to_string())),
            ("peak_bytes", Value::Num(s.peak_bytes as f64)),
            ("planned_peak_bytes", Value::Num(s.planned_peak as f64)),
            ("reuse_bytes", Value::Num(s.reuse_bytes as f64)),
            ("median_ms", Value::Num(s.median.as_secs_f64() * 1e3)),
        ]));
    }
    println!(
        "{name}: footprint {} -> {} ({:.0}% of per-buffer), reuse-saved {}",
        disc::util::fmt_bytes(off.peak_bytes as usize),
        disc::util::fmt_bytes(on.peak_bytes as usize),
        100.0 * on.peak_bytes as f64 / off.peak_bytes as f64,
        disc::util::fmt_bytes(on.reuse_bytes as usize),
    );
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let rounds: usize = if smoke { 6 } else { 24 };
    let batch_rounds: usize = if smoke { 4 } else { 12 };
    let compiler = DiscCompiler::new().expect("pjrt device");

    println!("=== Symbolic memory planning: repeat-binding replay, {rounds} rounds ===\n");
    let mut t = Table::new(&[
        "case", "planner", "peak(KiB)", "planned(KiB)", "reuse(KiB)", "median",
    ]);
    let mut rows: Vec<Value> = Vec::new();

    // --- solo replays: one binding, repeated with fresh contents ----------
    for w in [disc::workloads::transformer::workload(), disc::workloads::bert::workload()] {
        let seq = (w.seq_range.0 + w.seq_range.1) / 2;
        let mut rng = Prng::new(113);
        let requests: Vec<Vec<Tensor>> = (0..rounds).map(|_| (w.gen)(seq, &mut rng)).collect();
        let mut on = fresh(&compiler, &w, true);
        let mut off = fresh(&compiler, &w, false);
        let s_on = solo_sweep(&mut on, &requests);
        let s_off = solo_sweep(&mut off, &requests);
        gate(w.name, &s_on, &s_off, &mut rows, &mut t);
    }

    // --- stacked dispatches: one group shape, repeated ---------------------
    {
        let w = disc::workloads::transformer::workload();
        let mut rng = Prng::new(211);
        let group: [usize; 3] = [6, 9, 12];
        let rounds_in: Vec<Vec<Vec<Tensor>>> = (0..batch_rounds)
            .map(|_| group.iter().map(|&s| (w.gen)(s, &mut rng)).collect())
            .collect();
        let mut on = fresh(&compiler, &w, true);
        let mut off = fresh(&compiler, &w, false);
        let s_on = batch_sweep(&mut on, &rounds_in);
        let s_off = batch_sweep(&mut off, &rounds_in);
        gate("transformer(batch=3)", &s_on, &s_off, &mut rows, &mut t);
    }

    println!();
    t.print();

    let doc = obj(vec![
        ("bench", Value::Str("memplan".into())),
        ("rounds", Value::Num(rounds as f64)),
        ("smoke", Value::Bool(smoke)),
        ("rows", Value::Arr(rows)),
    ]);
    let path = disc::bench::artifact_path("BENCH_memplan.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote {}", path.display());
    println!(
        "\nReading guide: 'peak' is the replay arena's footprint high-water \
         (live + parked free-list bytes); planner-on acquires one planned \
         extent per replay, so its peak equals the planned slot layout, \
         while planner-off parks one free block per distinct buffer size. \
         'reuse' totals the bytes saved by slot sharing across the sweep."
    );
}
