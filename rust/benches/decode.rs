//! Decode serving: tokens/sec vs batch occupancy and per-token latency vs
//! sequence length, with the gates the CI smoke run (`DISC_BENCH_SMOKE=1`)
//! enforces:
//!
//! * **plan-family reuse**: a solo decode loop records exactly one plan
//!   per KV bucket — `plan_misses == kv_rollovers + 1`, every other step
//!   a replay hit (deterministic assert);
//! * **flat per-token latency**: stepping past a bucket rollover may pay
//!   one re-record, but amortized per-token wall time stays within a loose
//!   factor of the short-sequence run (timing gate, retried);
//! * **occupancy scales throughput**: continuous batching at `batch=4`
//!   beats `batch=1` tokens/sec on the same job set (timing gate,
//!   retried), with a **mid-flight join** at a step boundary demonstrated
//!   deterministically (`joins >= 1`);
//! * **bit-exactness**: every served job's token/probability stream equals
//!   a solo interpret-only step loop (deterministic assert — the same
//!   invariant the differential harness locks down).
//!
//! Writes `BENCH_decode.json` at the repo root (`bench::artifact_path`)
//! for the CI bench artifact.

use disc::bench::Table;
use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::coordinator::decode::{serve_decode, DecodeJob, DecodeServeOptions};
use disc::util::json::{to_string_pretty, Value};
use std::time::Instant;

fn fresh_model_opts(plan_cache: bool) -> CompiledModel {
    let compiler = DiscCompiler::new().expect("pjrt device");
    let g = disc::workloads::decode::graph();
    let module = disc::bridge::lower(&g).expect("lower");
    let mut opts = CompileOptions::mode(Mode::Disc);
    opts.plan_cache = plan_cache;
    if !plan_cache {
        opts.device_resident = false;
    }
    compiler.compile(module, &opts).expect("compile")
}

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::obj(fields)
}

/// Time one solo decode loop; returns (per-step seconds, DecodeOutput).
fn solo_loop(gen_steps: usize) -> (f64, disc::runtime::executor::DecodeOutput) {
    let spec = disc::workloads::decode::spec();
    let mut model = fresh_model_opts(true);
    let prompt = [7i64, 3];
    let t0 = Instant::now();
    let out = model.run_decode(&spec, &prompt, gen_steps).expect("decode loop");
    let dt = t0.elapsed();
    (dt.as_secs_f64() / out.steps as f64, out)
}

/// The deterministic job set the occupancy sweep serves: staggered
/// arrivals so the `batch=4` config must demonstrate mid-flight joins.
fn job_set(jobs: usize, gen_steps: usize) -> Vec<DecodeJob> {
    (0..jobs)
        .map(|i| DecodeJob {
            id: i as u64,
            prompt: vec![(i as i64 * 13 + 5) % 256, (i as i64 * 7 + 1) % 256],
            gen_steps,
            arrive_step: i as u64,
        })
        .collect()
}

fn main() {
    let smoke = std::env::var("DISC_BENCH_SMOKE").is_ok();
    let spec = disc::workloads::decode::spec();

    // --- plan-family reuse + flat per-token latency (solo loop) -----------
    // Short run stays inside the first 16-capacity bucket; the long run
    // crosses rollovers, paying one re-record per bucket and nothing else.
    let short_gen = 10; // 12 steps: one bucket
    let long_gen = if smoke { 28 } else { 58 }; // 30 / 60 steps: 1 / 3 rollovers
    let (_, short_out) = solo_loop(short_gen);
    assert_eq!(short_out.metrics.kv_rollovers, 0);
    assert_eq!(
        short_out.metrics.plan_misses, 1,
        "one plan family serves the whole first bucket"
    );
    let (_, long_out) = solo_loop(long_gen);
    assert!(long_out.metrics.kv_rollovers >= 1, "long loop must roll its bucket");
    assert_eq!(
        long_out.metrics.plan_misses,
        long_out.metrics.kv_rollovers + 1,
        "exactly one re-record per bucket rollover"
    );
    assert_eq!(
        long_out.metrics.plan_hits,
        long_out.steps as u64 - long_out.metrics.plan_misses,
        "every non-recording step replays"
    );

    // Timing half (retried: wall comparisons are noisy on shared runners).
    // Per-token latency may pay the re-records but must stay within a
    // loose factor of the short run — i.e. flat in sequence length, not
    // growing with it.
    let mut latency = None;
    for attempt in 0..3 {
        let (short_per_step, _) = solo_loop(short_gen);
        let (long_per_step, _) = solo_loop(long_gen);
        println!(
            "per-token latency: {:.1}us ({} steps) vs {:.1}us ({} steps) (attempt {attempt})",
            short_per_step * 1e6,
            short_gen + 2,
            long_per_step * 1e6,
            long_gen + 2,
        );
        if long_per_step < short_per_step * 3.0 {
            latency = Some((short_per_step, long_per_step));
            break;
        }
    }
    let (short_per_step, long_per_step) =
        latency.expect("per-token latency must stay flat across bucket rollovers");

    // --- bit-exactness: served streams == solo interpret-only loops -------
    let jobs_n = if smoke { 5 } else { 10 };
    let gen_steps = if smoke { 10 } else { 22 };
    let mut served = fresh_model_opts(true);
    let check_jobs = job_set(jobs_n, gen_steps);
    let check = serve_decode(
        &mut served,
        &spec,
        check_jobs,
        &DecodeServeOptions::batch(4).keep_probs(),
    )
    .expect("decode serve");
    assert_eq!(check.completed.len(), jobs_n);
    assert!(check.joins >= 1, "staggered arrivals must join mid-flight at a step boundary");
    assert!(check.batched_dispatches >= 1, "same-capacity steps must stack");
    let mut interp = fresh_model_opts(false);
    for (job, c) in job_set(jobs_n, gen_steps).iter().zip(&check.completed) {
        assert_eq!(job.id, c.id, "completions are id-sorted over a full set");
        let want = interp.run_decode(&spec, &job.prompt, job.gen_steps).expect("interpret loop");
        assert_eq!(c.generated, want.generated, "job {}: served tokens diverged", c.id);
        assert_eq!(
            c.probs.as_ref().unwrap(),
            &want.step_probs,
            "job {}: served probs diverged from the solo interpreter",
            c.id
        );
    }

    // --- occupancy sweep: tokens/sec vs batch size (retried gate) ---------
    println!("\n=== Decode serving: {jobs_n} jobs x {} steps each ===\n", gen_steps + 2);
    let mut t = Table::new(&[
        "batch", "tok/s", "dispatches", "batched", "max-occ", "joins", "rollovers", "kv-peak(KiB)",
    ]);
    let mut rows: Vec<Value> = Vec::new();
    let mut gate = None;
    for attempt in 0..3 {
        let mut reports = Vec::new();
        for &batch in &[1usize, 4] {
            let mut model = fresh_model_opts(true);
            let report = serve_decode(
                &mut model,
                &spec,
                job_set(jobs_n, gen_steps),
                &DecodeServeOptions::batch(batch),
            )
            .expect("decode serve");
            assert_eq!(report.completed.len(), jobs_n, "batch={batch}: all jobs complete");
            reports.push((batch, report));
        }
        let solo_tps = reports[0].1.tokens_per_sec;
        let batched_tps = reports[1].1.tokens_per_sec;
        println!(
            "occupancy sweep: batch=1 {solo_tps:.0} tok/s vs batch=4 {batched_tps:.0} tok/s \
             (attempt {attempt})"
        );
        if batched_tps > solo_tps || attempt == 2 {
            gate = Some(reports);
            break;
        }
    }
    let reports = gate.expect("sweep ran");
    for (batch, report) in &reports {
        let m = &report.metrics;
        t.row(&[
            batch.to_string(),
            format!("{:.0}", report.tokens_per_sec),
            report.dispatches.to_string(),
            report.batched_dispatches.to_string(),
            report.max_occupancy.to_string(),
            report.joins.to_string(),
            m.kv_rollovers.to_string(),
            format!("{:.1}", m.kv_resident_bytes as f64 / 1024.0),
        ]);
        rows.push(obj(vec![
            ("batch", Value::Num(*batch as f64)),
            ("jobs", Value::Num(report.offered as f64)),
            ("total_steps", Value::Num(report.total_steps as f64)),
            ("tokens_per_sec", Value::Num(report.tokens_per_sec)),
            ("dispatches", Value::Num(report.dispatches as f64)),
            ("batched_dispatches", Value::Num(report.batched_dispatches as f64)),
            ("max_occupancy", Value::Num(report.max_occupancy as f64)),
            ("joins", Value::Num(report.joins as f64)),
            ("kv_rollovers", Value::Num(m.kv_rollovers as f64)),
            ("kv_peak_bytes", Value::Num(m.kv_resident_bytes as f64)),
            ("plan_hits", Value::Num(m.plan_hits as f64)),
            ("plan_misses", Value::Num(m.plan_misses as f64)),
        ]));
    }
    t.print();
    assert!(
        reports[1].1.tokens_per_sec > reports[0].1.tokens_per_sec,
        "occupancy must scale decode throughput: batch=4 {:.0} tok/s vs batch=1 {:.0} tok/s",
        reports[1].1.tokens_per_sec,
        reports[0].1.tokens_per_sec
    );
    assert_eq!(reports[0].1.joins, 0, "batch=1 admits only into an empty batch");
    assert!(reports[1].1.joins >= 1, "batch=4 must join mid-flight");

    let doc = obj(vec![
        ("bench", Value::Str("decode".into())),
        ("workload", Value::Str("decode".into())),
        ("smoke", Value::Bool(smoke)),
        (
            "solo",
            obj(vec![
                ("short_steps", Value::Num((short_gen + 2) as f64)),
                ("long_steps", Value::Num((long_gen + 2) as f64)),
                ("short_us_per_token", Value::Num(short_per_step * 1e6)),
                ("long_us_per_token", Value::Num(long_per_step * 1e6)),
                ("long_rollovers", Value::Num(long_out.metrics.kv_rollovers as f64)),
                ("long_plan_misses", Value::Num(long_out.metrics.plan_misses as f64)),
                ("long_plan_hits", Value::Num(long_out.metrics.plan_hits as f64)),
            ]),
        ),
        ("rows", Value::Arr(rows)),
    ]);
    let path = disc::bench::artifact_path("BENCH_decode.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write bench artifact");
    println!("\nwrote {}", path.display());
}
