//! Ablations over DISC's design choices (DESIGN.md §5):
//!
//!  1. shape-constraint collection on/off (fusion scope, §4.2.1);
//!  2. input fusion (reduce-rooted) on/off (§4.3 templates);
//!  3. bucket policy: pow2 vs multiple-of-16 vs exact (§4.3 adaptive
//!     configuration vs per-shape compilation);
//!  4. pooled (cached) allocator on/off (§4.2.2);
//!  5. launch-plan cache + device-resident replay on/off (the per-request
//!     host-overhead tier; see docs/runtime.md);
//!  6. persistent device-weight cache on/off (GEMM weights upload once per
//!     program vs per call — the h2d column isolates the saved traffic);
//!  7. symbolic memory planning on/off (replays acquire one planned arena
//!     extent vs per-buffer blocks; see runtime/memplan.rs).

use disc::bench::Table;
use disc::codegen::BucketPolicy;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::coordinator::serve_closed_loop;
use disc::fusion::FusionOptions;

const REQUESTS: usize = 20;
const SEED: u64 = 55;

struct Case {
    name: &'static str,
    opts: CompileOptions,
}

fn main() {
    let compiler = DiscCompiler::new().expect("pjrt device");
    let w = disc::workloads::transformer::workload();

    let base = CompileOptions::mode(Mode::Disc);
    let cases = vec![
        Case { name: "disc (full)", opts: base.clone() },
        Case {
            name: "no shape constraints",
            opts: CompileOptions {
                fusion: Some(FusionOptions { use_constraints: false, ..Default::default() }),
                ..base.clone()
            },
        },
        Case {
            name: "no input fusion",
            opts: CompileOptions {
                fusion: Some(FusionOptions { enable_input_fusion: false, ..Default::default() }),
                ..base.clone()
            },
        },
        Case {
            name: "no fusion at all",
            opts: CompileOptions {
                fusion: Some(FusionOptions { enabled: false, ..Default::default() }),
                ..base.clone()
            },
        },
        Case {
            name: "buckets: multiple-of-16",
            opts: CompileOptions { policy: Some(BucketPolicy::MultipleOf(16)), ..base.clone() },
        },
        Case {
            name: "buckets: exact (per-shape)",
            opts: CompileOptions { policy: Some(BucketPolicy::Exact), ..base.clone() },
        },
        Case {
            name: "no buffer pooling",
            opts: CompileOptions { pooled_buffers: false, ..base.clone() },
        },
        Case {
            name: "no launch-plan cache",
            opts: CompileOptions {
                plan_cache: false,
                device_resident: false,
                ..base.clone()
            },
        },
        Case {
            name: "plans, host-resident",
            opts: CompileOptions { device_resident: false, ..base.clone() },
        },
        Case {
            name: "no device weight cache",
            opts: CompileOptions {
                runtime: base.runtime.clone().with_weight_cache(false),
                ..base.clone()
            },
        },
        Case {
            name: "no symbolic memory plan",
            opts: CompileOptions {
                runtime: base.runtime.clone().with_memory_plan(false),
                ..base.clone()
            },
        },
    ];

    println!("=== Ablations: transformer, {REQUESTS} dynamic-length requests ===\n");
    let mut t = Table::new(&[
        "variant",
        "groups",
        "mem-kernels",
        "compiles",
        "pad-copies",
        "pad-ratio",
        "pool-hit%",
        "h2d",
        "wall",
    ]);
    for case in cases {
        let module = disc::bridge::lower(&w.graph).expect("lower");
        let mut model = compiler.compile(module, &case.opts).expect("compile");
        for inputs in w.request_stream(3, SEED + 1) {
            model.run(&inputs).expect("warmup");
        }
        let report =
            serve_closed_loop(&mut model, w.request_stream(REQUESTS, SEED)).expect("serve");
        let m = &report.metrics;
        let hit = if m.allocs > 0 {
            format!("{:.0}%", 100.0 * m.pool_hits as f64 / m.allocs as f64)
        } else {
            "-".to_string()
        };
        t.row(&[
            case.name.to_string(),
            model.report.fusion_groups.to_string(),
            m.mem_kernels.to_string(),
            m.compile_events.to_string(),
            m.pad_copies.to_string(),
            format!("{:.4}", m.padding_ratio()),
            hit,
            disc::util::fmt_bytes(m.h2d_bytes as usize),
            format!("{:.2?}", report.wall),
        ]);
    }
    t.print();
    println!(
        "\nReading guide: constraints widen fusion (fewer mem-kernels); \
         exact buckets recompile per shape (compile column) but pad \
         nothing, wider buckets trade padded elements (pad-ratio column) \
         for kernel reuse; pooling trades allocator traffic for reuse; the \
         weight-cache row re-uploads GEMM weights every call (h2d column)."
    );
}
