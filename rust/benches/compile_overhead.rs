//! The §2 motivation: static-shape compilers recompile for every emerging
//! shape ("XLA is usually closed for dynamic shape workloads to prevent
//! negative optimization"); DISC compiles once per pattern×bucket.
//!
//! The transformer workload serves a stream of N *distinct* sequence
//! lengths under (a) the XLA-like exact-shape cache and (b) DISC's
//! bucketed shape-agnostic cache. Reported: cumulative compile events,
//! compile time, and cache entries as the shape count grows.

use disc::bench::Table;
use disc::codegen::BucketPolicy;
use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::util::prng::Prng;

fn main() {
    let compiler = DiscCompiler::new().expect("pjrt device");
    let w = disc::workloads::transformer::workload();

    println!("=== Compilation overhead vs number of distinct shapes ===\n");
    let mut t = Table::new(&[
        "distinct shapes", "xla-like compiles", "xla-like time", "disc compiles", "disc time",
    ]);

    // One long stream of distinct lengths, measured cumulatively.
    let mut rng = Prng::new(1234);
    let mut lengths: Vec<usize> = (16..=96).collect();
    // Shuffle deterministically.
    for i in (1..lengths.len()).rev() {
        let j = rng.below(i + 1);
        lengths.swap(i, j);
    }

    let mut opts_static = CompileOptions::mode(Mode::Disc);
    opts_static.policy = Some(BucketPolicy::Exact);
    let m1 = disc::bridge::lower(&w.graph).expect("lower");
    let mut xla_like = compiler.compile(m1, &opts_static).expect("compile");

    let m2 = disc::bridge::lower(&w.graph).expect("lower");
    let mut disc_model =
        compiler.compile(m2, &CompileOptions::mode(Mode::Disc)).expect("compile");

    let checkpoints = [5usize, 10, 20, 40, 80];
    let mut served = 0usize;
    let mut gen_rng = Prng::new(5);
    for &cp in &checkpoints {
        while served < cp.min(lengths.len()) {
            let seq = lengths[served];
            let inputs = (w.gen)(seq, &mut gen_rng);
            xla_like.run(&inputs).expect("xla-like run");
            disc_model.run(&inputs).expect("disc run");
            served += 1;
        }
        let xs = xla_like.cache_stats().unwrap();
        let ds = disc_model.cache_stats().unwrap();
        t.row(&[
            served.to_string(),
            xs.misses.to_string(),
            format!("{:.2?}", xs.compile_time),
            ds.misses.to_string(),
            format!("{:.2?}", ds.compile_time),
        ]);
    }
    t.print();

    let xs = xla_like.cache_stats().unwrap();
    let ds = disc_model.cache_stats().unwrap();
    println!(
        "\nafter {} distinct shapes: exact-shape cache holds {} executables \
         ({:.2?} compiling), DISC holds {} ({:.2?}) — compile cost growth is \
         O(shapes) vs O(log shapes).",
        served, xs.entries, xs.compile_time, ds.entries, ds.compile_time
    );
}
