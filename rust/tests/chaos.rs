//! Chaos smoke: end-to-end serving under injected faults.
//!
//! The gate (see ISSUE/ROADMAP robustness item): `serve_open_loop` with a
//! fault schedule armed must lose **zero** requests — every offered
//! request is either answered bit-identically to a fault-free reference
//! (the solo interpreter or the host reference evaluator, whichever rung
//! of the degradation ladder served it) or counted in `shed_requests` /
//! `deadline_misses`. Fault-free runs must show zero demotions, retries,
//! and restarts.
//!
//! The schedule comes from the `DISC_FAULTS` environment spec (the CI
//! chaos matrix sweeps compile-fail, device-OOM, and worker-panic seeds)
//! and falls back to a built-in spec that arms every seam, so a plain
//! `cargo test --test chaos` exercises the same paths. With
//! `DISC_BENCH_SMOKE=1` the run also writes a `BENCH_chaos.json`
//! artifact with the per-site fire counts and robustness counters.

use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::coordinator::{serve_open_loop, ServeOptions, ServeReport};
use disc::runtime::faults::{FaultPlan, FaultSite, SITES};
use disc::runtime::tensor::Tensor;
use std::sync::Arc;

/// Every seam armed: moderate compile/transfer/OOM rates with small caps
/// (so the stream recovers) plus two guaranteed worker panics.
const DEFAULT_SPEC: &str = "seed=23,compile=150:4,h2d=100:3,d2h=100:3,oom=150:4,panic=1000:2";

/// The armed schedule: the CI matrix env spec, or the built-in default.
fn armed_plan() -> Arc<FaultPlan> {
    FaultPlan::from_env().unwrap_or_else(|| Arc::new(FaultPlan::parse(DEFAULT_SPEC).unwrap()))
}

/// A schedule that never fires — pins serving to fault-free behavior even
/// when the chaos matrix exports `DISC_FAULTS` for this process.
fn no_faults() -> Arc<FaultPlan> {
    Arc::new(FaultPlan::parse("seed=1").unwrap())
}

fn compile_transformer(faults: Option<Arc<FaultPlan>>, opts: &CompileOptions) -> CompiledModel {
    let w = disc::workloads::by_name("transformer").unwrap();
    let compiler = DiscCompiler::with_faults(faults).unwrap();
    compiler.compile(disc::bridge::lower(&w.graph).unwrap(), opts).unwrap()
}

/// Fault-free references for the stream: the solo interpreter (plan cache
/// and device residency off) and the host reference evaluator — the two
/// fault-free answer sources the degradation ladder can bottom out on.
fn references(stream: &[Vec<Tensor>]) -> (Vec<Vec<Tensor>>, Vec<Vec<Tensor>>) {
    let mut interp_opts = CompileOptions::mode(Mode::Disc);
    interp_opts.plan_cache = false;
    interp_opts.device_resident = false;
    let mut interp = compile_transformer(None, &interp_opts);
    let want_interp: Vec<Vec<Tensor>> =
        stream.iter().map(|r| interp.run(r).unwrap().outputs).collect();
    let module = interp.module().clone();
    let want_ref: Vec<Vec<Tensor>> = stream
        .iter()
        .map(|r| disc::runtime::reference::eval_module(&module, r).unwrap().outputs)
        .collect();
    (want_interp, want_ref)
}

#[test]
fn serving_under_faults_loses_nothing_and_answers_bit_exactly() {
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(24, 77);
    let (want_interp, want_ref) = references(&stream);

    let plan = armed_plan();
    let mut model = compile_transformer(Some(plan.clone()), &CompileOptions::mode(Mode::Disc));
    let opts = ServeOptions::rate(20_000.0)
        .workers(2)
        .batch(3)
        .batch_window_us(100)
        .faults(plan.clone())
        .keep_outputs();
    let report = serve_open_loop(&mut model, stream, &opts).unwrap();

    // Zero lost requests, with faults firing: completed + shed +
    // deadline-missed reconciles to the offered stream.
    assert_eq!(
        report.completed as u64 + report.metrics.shed_requests + report.metrics.deadline_misses,
        24,
        "request accounting must balance under faults"
    );

    // Every answered request is bit-identical to a fault-free reference:
    // the solo interpreter (replay/interpret rungs, batched or solo) or
    // the host reference evaluator (the bottom rung).
    assert_eq!(report.outputs.len(), report.completed);
    for (id, got) in &report.outputs {
        let i = *id as usize;
        assert!(
            got == &want_interp[i] || got == &want_ref[i],
            "request {id} diverged from both fault-free references"
        );
    }

    // Every injected worker panic surfaced as exactly one supervised
    // restart; when the schedule arms the panic seam at all, at least one
    // restart must be on the books.
    assert_eq!(report.metrics.worker_restarts, plan.fired(FaultSite::WorkerPanic));
    if plan.arms(FaultSite::WorkerPanic) {
        assert!(report.metrics.worker_restarts >= 1, "armed panic seam never restarted");
    }

    if std::env::var("DISC_BENCH_SMOKE").is_ok() {
        write_bench_artifact(&plan, &report);
    }
}

#[test]
fn fault_free_serving_shows_zero_demotions() {
    // The regression half of the gate: with no faults armed, the ladder
    // never demotes, nothing retries or sheds, and no worker restarts —
    // robustness must be free when nothing fails. `no_faults()` pins both
    // the device and the coordinator even if `DISC_FAULTS` is exported.
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(12, 78);
    let mut model = compile_transformer(Some(no_faults()), &CompileOptions::mode(Mode::Disc));
    let report = serve_open_loop(
        &mut model,
        stream,
        &ServeOptions::rate(20_000.0).workers(2).faults(no_faults()),
    )
    .unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.metrics.demotions, 0, "fault-free serving must never demote");
    assert_eq!(report.metrics.retries, 0);
    assert_eq!(report.metrics.worker_restarts, 0);
    assert_eq!(report.metrics.shed_requests, 0);
    assert_eq!(report.metrics.deadline_misses, 0);
}

#[test]
fn deadlines_shed_under_injected_overload() {
    // Deadlines + faults compose: with every dispatch panicking until the
    // requeue budget burns, a tight deadline converts the requeue churn
    // into explicit shed/deadline accounting instead of unbounded retry.
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(6, 79);
    let plan = Arc::new(FaultPlan::parse("seed=31,panic=1000").unwrap());
    let mut model = compile_transformer(Some(no_faults()), &CompileOptions::mode(Mode::Disc));
    let report = serve_open_loop(
        &mut model,
        stream,
        &ServeOptions::rate(50_000.0).deadline_ms(60_000).max_requeues(1).faults(plan),
    )
    .unwrap();
    // Every dispatch panics: each request burns its single requeue and is
    // then shed (the generous deadline never fires here).
    assert_eq!(report.completed, 0);
    assert_eq!(report.metrics.shed_requests, 6);
    assert_eq!(report.metrics.deadline_misses, 0);
    assert!(report.metrics.worker_restarts >= 6, "two dispatch attempts per request");
}

fn write_bench_artifact(plan: &FaultPlan, report: &ServeReport) {
    use disc::util::json::{to_string_pretty, Value};
    let sites: Vec<Value> = SITES
        .iter()
        .map(|&s| {
            Value::obj(vec![
                ("site", Value::Str(s.key().to_string())),
                ("calls", Value::Num(plan.calls(s) as f64)),
                ("fired", Value::Num(plan.fired(s) as f64)),
            ])
        })
        .collect();
    let m = &report.metrics;
    let doc = Value::obj(vec![
        ("bench", Value::Str("chaos".into())),
        ("workload", Value::Str("transformer".into())),
        ("seed", Value::Num(plan.seed() as f64)),
        ("completed", Value::Num(report.completed as f64)),
        ("shed_requests", Value::Num(m.shed_requests as f64)),
        ("deadline_misses", Value::Num(m.deadline_misses as f64)),
        ("retries", Value::Num(m.retries as f64)),
        ("demotions", Value::Num(m.demotions as f64)),
        ("worker_restarts", Value::Num(m.worker_restarts as f64)),
        ("throughput_rps", Value::Num(report.throughput_rps)),
        ("sites", Value::Arr(sites)),
    ]);
    std::fs::write("BENCH_chaos.json", to_string_pretty(&doc)).expect("write chaos artifact");
    println!("wrote BENCH_chaos.json");
}
