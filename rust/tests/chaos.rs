//! Chaos smoke: end-to-end serving under injected faults.
//!
//! The gate (see ISSUE/ROADMAP robustness item): `serve_open_loop` with a
//! fault schedule armed must lose **zero** requests — every offered
//! request is either answered bit-identically to a fault-free reference
//! (the solo interpreter or the host reference evaluator, whichever rung
//! of the degradation ladder served it) or counted in `shed_requests` /
//! `deadline_misses`. Fault-free runs must show zero demotions, retries,
//! and restarts.
//!
//! The schedule comes from the `DISC_FAULTS` environment spec (the CI
//! chaos matrix sweeps compile-fail, device-OOM, and worker-panic seeds)
//! and falls back to a built-in spec that arms every seam, so a plain
//! `cargo test --test chaos` exercises the same paths. With
//! `DISC_BENCH_SMOKE=1` the run also writes a `BENCH_chaos.json`
//! artifact with the per-site fire counts and robustness counters.

//! The decode tests extend the same gate to the autoregressive step loop:
//! KV-slab OOM at admission/rollover must demote residency (never the
//! request), a worker panic mid-decode must restart the engine and replay
//! the in-flight step from the scheduler-owned KV state, and in both cases
//! every completed job's token/probability stream must be bit-identical
//! to a fault-free solo loop.

use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::coordinator::decode::{serve_decode, DecodeJob, DecodeServeOptions};
use disc::coordinator::{serve_open_loop, ServeOptions, ServeReport};
use disc::runtime::faults::{FaultPlan, FaultSite, SITES};
use disc::runtime::tensor::Tensor;
use std::sync::Arc;

/// Every seam armed: moderate compile/transfer/OOM rates with small caps
/// (so the stream recovers) plus two guaranteed worker panics.
const DEFAULT_SPEC: &str = "seed=23,compile=150:4,h2d=100:3,d2h=100:3,oom=150:4,panic=1000:2";

/// The armed schedule: the CI matrix env spec, or the built-in default.
fn armed_plan() -> Arc<FaultPlan> {
    FaultPlan::from_env().unwrap_or_else(|| Arc::new(FaultPlan::parse(DEFAULT_SPEC).unwrap()))
}

/// A schedule that never fires — pins serving to fault-free behavior even
/// when the chaos matrix exports `DISC_FAULTS` for this process.
fn no_faults() -> Arc<FaultPlan> {
    Arc::new(FaultPlan::parse("seed=1").unwrap())
}

fn compile_transformer(faults: Option<Arc<FaultPlan>>, opts: &CompileOptions) -> CompiledModel {
    let w = disc::workloads::by_name("transformer").unwrap();
    let compiler = DiscCompiler::with_faults(faults).unwrap();
    compiler.compile(disc::bridge::lower(&w.graph).unwrap(), opts).unwrap()
}

/// Fault-free references for the stream: the solo interpreter (plan cache
/// and device residency off) and the host reference evaluator — the two
/// fault-free answer sources the degradation ladder can bottom out on.
fn references(stream: &[Vec<Tensor>]) -> (Vec<Vec<Tensor>>, Vec<Vec<Tensor>>) {
    let mut interp_opts = CompileOptions::mode(Mode::Disc);
    interp_opts.plan_cache = false;
    interp_opts.device_resident = false;
    let mut interp = compile_transformer(None, &interp_opts);
    let want_interp: Vec<Vec<Tensor>> =
        stream.iter().map(|r| interp.run(r).unwrap().outputs).collect();
    let module = interp.module().clone();
    let want_ref: Vec<Vec<Tensor>> = stream
        .iter()
        .map(|r| disc::runtime::reference::eval_module(&module, r).unwrap().outputs)
        .collect();
    (want_interp, want_ref)
}

#[test]
fn serving_under_faults_loses_nothing_and_answers_bit_exactly() {
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(24, 77);
    let (want_interp, want_ref) = references(&stream);

    let plan = armed_plan();
    let mut model = compile_transformer(Some(plan.clone()), &CompileOptions::mode(Mode::Disc));
    let opts = ServeOptions::rate(20_000.0)
        .workers(2)
        .batch(3)
        .batch_window_us(100)
        .faults(plan.clone())
        .keep_outputs();
    let report = serve_open_loop(&mut model, stream, &opts).unwrap();

    // Zero lost requests, with faults firing: completed + shed +
    // deadline-missed reconciles to the offered stream.
    assert_eq!(
        report.completed as u64 + report.metrics.shed_requests + report.metrics.deadline_misses,
        24,
        "request accounting must balance under faults"
    );

    // Every answered request is bit-identical to a fault-free reference:
    // the solo interpreter (replay/interpret rungs, batched or solo) or
    // the host reference evaluator (the bottom rung).
    assert_eq!(report.outputs.len(), report.completed);
    for (id, got) in &report.outputs {
        let i = *id as usize;
        assert!(
            got == &want_interp[i] || got == &want_ref[i],
            "request {id} diverged from both fault-free references"
        );
    }

    // Every injected worker panic surfaced as exactly one supervised
    // restart; when the schedule arms the panic seam at all, at least one
    // restart must be on the books.
    assert_eq!(report.metrics.worker_restarts, plan.fired(FaultSite::WorkerPanic));
    if plan.arms(FaultSite::WorkerPanic) {
        assert!(report.metrics.worker_restarts >= 1, "armed panic seam never restarted");
    }

    if std::env::var("DISC_BENCH_SMOKE").is_ok() {
        write_bench_artifact(&plan, &report);
    }
}

#[test]
fn fault_free_serving_shows_zero_demotions() {
    // The regression half of the gate: with no faults armed, the ladder
    // never demotes, nothing retries or sheds, and no worker restarts —
    // robustness must be free when nothing fails. `no_faults()` pins both
    // the device and the coordinator even if `DISC_FAULTS` is exported.
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(12, 78);
    let mut model = compile_transformer(Some(no_faults()), &CompileOptions::mode(Mode::Disc));
    let report = serve_open_loop(
        &mut model,
        stream,
        &ServeOptions::rate(20_000.0).workers(2).faults(no_faults()),
    )
    .unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.metrics.demotions, 0, "fault-free serving must never demote");
    assert_eq!(report.metrics.retries, 0);
    assert_eq!(report.metrics.worker_restarts, 0);
    assert_eq!(report.metrics.shed_requests, 0);
    assert_eq!(report.metrics.deadline_misses, 0);
}

#[test]
fn deadlines_shed_under_injected_overload() {
    // Deadlines + faults compose: with every dispatch panicking until the
    // requeue budget burns, a tight deadline converts the requeue churn
    // into explicit shed/deadline accounting instead of unbounded retry.
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(6, 79);
    let plan = Arc::new(FaultPlan::parse("seed=31,panic=1000").unwrap());
    let mut model = compile_transformer(Some(no_faults()), &CompileOptions::mode(Mode::Disc));
    let report = serve_open_loop(
        &mut model,
        stream,
        &ServeOptions::rate(50_000.0).deadline_ms(60_000).max_requeues(1).faults(plan),
    )
    .unwrap();
    // Every dispatch panics: each request burns its single requeue and is
    // then shed (the generous deadline never fires here).
    assert_eq!(report.completed, 0);
    assert_eq!(report.metrics.shed_requests, 6);
    assert_eq!(report.metrics.deadline_misses, 0);
    assert!(report.metrics.worker_restarts >= 6, "two dispatch attempts per request");
}

fn compile_decode(faults: Option<Arc<FaultPlan>>, opts: &CompileOptions) -> CompiledModel {
    let w = disc::workloads::by_name("decode").unwrap();
    let compiler = DiscCompiler::with_faults(faults).unwrap();
    compiler.compile(disc::bridge::lower(&w.graph).unwrap(), opts).unwrap()
}

/// Fault-free solo decode loops — the reference every chaos-run job must
/// match bit-for-bit.
fn decode_references(
    spec: &disc::runtime::kv::DecodeSpec,
    cases: &[(&[i64], usize)],
) -> Vec<disc::runtime::executor::DecodeOutput> {
    let mut clean = compile_decode(Some(no_faults()), &CompileOptions::mode(Mode::Disc));
    cases.iter().map(|(p, g)| clean.run_decode(spec, p, *g).unwrap()).collect()
}

#[test]
fn decode_kv_oom_demotes_residency_and_stays_bit_exact() {
    // Hammer the device-OOM seam with a fixed seed: KV-slab acquisitions
    // (at admission and at bucket rollover — the long job rolls 16 → 32)
    // fail, demoting the slab to host residency. The request itself never
    // degrades: the step loop keeps running and its stream stays
    // bit-identical to the fault-free reference.
    let spec = disc::workloads::decode::spec();
    let cases: [(&[i64], usize); 3] = [(&[3, 1, 4], 16), (&[2, 7], 9), (&[5], 7)];
    let want = decode_references(&spec, &cases);

    let plan = Arc::new(FaultPlan::parse("seed=41,oom=500:6").unwrap());
    let mut model = compile_decode(Some(plan.clone()), &CompileOptions::mode(Mode::Disc));
    let jobs: Vec<DecodeJob> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, g))| DecodeJob {
            id: i as u64,
            prompt: p.to_vec(),
            gen_steps: *g,
            arrive_step: i as u64,
        })
        .collect();
    let opts = DecodeServeOptions::batch(2).faults(no_faults()).keep_probs();
    let report = serve_decode(&mut model, &spec, jobs, &opts).unwrap();

    let m = &report.metrics;
    assert_eq!(
        report.completed.len() as u64 + m.shed_requests + m.deadline_misses,
        3,
        "decode accounting must balance under OOM injection"
    );
    assert_eq!(report.completed.len(), 3, "OOM demotes residency, never the request");
    assert!(m.kv_rollovers >= 1, "the 19-step job must roll its bucket");
    if plan.fired(FaultSite::DeviceOom) > 0 {
        assert!(m.demotions >= 1, "fired OOM must surface as demotions");
    }
    for c in &report.completed {
        let want = &want[c.id as usize];
        assert_eq!(c.generated, want.generated, "job {}: tokens under OOM", c.id);
        assert_eq!(
            c.probs.as_ref().unwrap(),
            &want.step_probs,
            "job {}: probs under OOM",
            c.id
        );
    }
    assert_eq!(model.kv_residency().0, 0, "all slab bytes released at drain");
}

#[test]
fn decode_panic_mid_loop_restarts_and_streams_match() {
    // Two guaranteed worker panics interrupt decode dispatches mid-loop.
    // Supervision restarts the engine; the scheduler-owned KV caches
    // survive, so the interrupted step replays bit-identically and the
    // finished streams match a fault-free run.
    let spec = disc::workloads::decode::spec();
    let cases: [(&[i64], usize); 2] = [(&[4, 2], 12), (&[9], 10)];
    let want = decode_references(&spec, &cases);

    let plan = Arc::new(FaultPlan::parse("seed=42,panic=1000:2").unwrap());
    let mut model = compile_decode(Some(no_faults()), &CompileOptions::mode(Mode::Disc));
    let jobs: Vec<DecodeJob> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, g))| DecodeJob::new(i as u64, p.to_vec(), *g))
        .collect();
    let opts = DecodeServeOptions::batch(2).max_requeues(2).faults(plan.clone()).keep_probs();
    let report = serve_decode(&mut model, &spec, jobs, &opts).unwrap();

    let m = &report.metrics;
    assert_eq!(m.worker_restarts, plan.fired(FaultSite::WorkerPanic));
    assert!(m.worker_restarts >= 1, "armed panic seam never restarted");
    assert_eq!(report.completed.len(), 2, "requeued jobs finish after restarts");
    assert_eq!(
        report.completed.len() as u64 + m.shed_requests + m.deadline_misses,
        2,
        "decode accounting must balance under panic injection"
    );
    for c in &report.completed {
        let want = &want[c.id as usize];
        assert_eq!(c.generated, want.generated, "job {}: restart must not fork", c.id);
        assert_eq!(
            c.probs.as_ref().unwrap(),
            &want.step_probs,
            "job {}: probs across restart",
            c.id
        );
    }
    assert_eq!(model.kv_residency().0, 0, "all slab bytes released at drain");
}

#[test]
fn multi_tenant_storm_leaves_the_latency_tenant_untouched() {
    // The bulkhead gate, under whatever seams the chaos matrix armed: a
    // latency-bound transformer tenant rides alongside a flooding
    // throughput tenant that is also the target of the worker-panic
    // schedule. No matter which matrix line runs this,
    //
    //   * the zero-lost invariant reconciles PER TENANT (serve_mix asserts
    //     it internally; the balance is spot-checked here),
    //   * the latency tenant loses nothing: everything completes, nothing
    //     sheds, no restarts, no quarantine, no breaker trips,
    //   * the latency tenant's answers stay bit-exact — against the solo
    //     interpreter alone when no device seam is armed (it must also
    //     show zero demotions then), against the interpreter-or-reference
    //     disjunction when device faults can demote its dispatches,
    //   * every injected panic is attributed to the target tenant as
    //     exactly one supervised restart.
    use disc::coordinator::tenants::{serve_mix, MixOptions, TenantSpec};

    let plan = armed_plan();
    let n_lat = 16;
    let lat_seed = 83;
    let w = disc::workloads::by_name("transformer").unwrap();
    let stream = w.request_stream(n_lat, lat_seed);
    let (want_interp, want_ref) = references(&stream);

    let specs = vec![
        TenantSpec::latency("lat", "transformer").requests(n_lat).rate(600.0).seed(lat_seed),
        TenantSpec::throughput("flood", "tts")
            .requests(48)
            .rate(4_000.0)
            .seed(84)
            .bursty(12)
            .fault_target(),
    ];
    let report = serve_mix(
        specs,
        &MixOptions::new().workers(2).batch(3).faults(plan.clone()).breaker(2, 2).keep_outputs(),
    )
    .unwrap();

    for t in &report.tenants {
        let m = &t.report.metrics;
        assert_eq!(
            t.report.completed as u64 + m.shed_requests + m.deadline_misses,
            t.offered as u64,
            "tenant {}: accounting must balance under the storm",
            t.name
        );
    }

    let healthy = &report.tenants[0];
    let faulty = &report.tenants[1];
    let hm = &healthy.report.metrics;
    assert_eq!(healthy.report.completed, n_lat, "latency tenant must complete everything");
    assert_eq!(hm.shed_requests, 0, "latency tenant must shed nothing");
    assert_eq!(hm.worker_restarts, 0, "panic faults must never land on the latency tenant");
    assert_eq!(hm.quarantined, 0);
    assert_eq!(healthy.breaker_trips, 0, "healthy tenants keep full service");

    let device_armed = [
        FaultSite::Compile,
        FaultSite::CompilePanic,
        FaultSite::H2d,
        FaultSite::D2h,
        FaultSite::DeviceOom,
    ]
    .iter()
    .any(|&s| plan.arms(s));
    if !device_armed {
        assert_eq!(hm.demotions, 0, "no device seam armed: the ladder must never demote");
    }
    assert_eq!(healthy.report.outputs.len(), n_lat);
    for (id, got) in &healthy.report.outputs {
        let i = *id as usize;
        if device_armed {
            assert!(
                got == &want_interp[i] || got == &want_ref[i],
                "latency request {id} diverged from both fault-free references"
            );
        } else {
            assert_eq!(got, &want_interp[i], "latency request {id} diverged from solo");
        }
    }

    // Attribution: the panic seam is consulted only inside the target
    // tenant's dispatches, so every fire is one of ITS restarts.
    assert_eq!(faulty.report.metrics.worker_restarts, plan.fired(FaultSite::WorkerPanic));
    if faulty.breaker_trips > 0 {
        assert!(
            faulty.report.metrics.quarantined > 0,
            "an open breaker must quarantine subsequent dispatches"
        );
    }
}

fn write_bench_artifact(plan: &FaultPlan, report: &ServeReport) {
    use disc::util::json::{to_string_pretty, Value};
    let sites: Vec<Value> = SITES
        .iter()
        .map(|&s| {
            Value::obj(vec![
                ("site", Value::Str(s.key().to_string())),
                ("calls", Value::Num(plan.calls(s) as f64)),
                ("fired", Value::Num(plan.fired(s) as f64)),
            ])
        })
        .collect();
    let m = &report.metrics;
    let doc = Value::obj(vec![
        ("bench", Value::Str("chaos".into())),
        ("workload", Value::Str("transformer".into())),
        ("seed", Value::Num(plan.seed() as f64)),
        ("completed", Value::Num(report.completed as f64)),
        ("shed_requests", Value::Num(m.shed_requests as f64)),
        ("deadline_misses", Value::Num(m.deadline_misses as f64)),
        ("retries", Value::Num(m.retries as f64)),
        ("demotions", Value::Num(m.demotions as f64)),
        ("worker_restarts", Value::Num(m.worker_restarts as f64)),
        ("throughput_rps", Value::Num(report.throughput_rps)),
        ("sites", Value::Arr(sites)),
    ]);
    let path = disc::bench::artifact_path("BENCH_chaos.json");
    std::fs::write(&path, to_string_pretty(&doc)).expect("write chaos artifact");
    println!("wrote {}", path.display());
}
