//! Cross-module integration + property tests.
//!
//! A seeded random-graph generator produces arbitrary well-typed frontend
//! graphs with dynamic shapes; every graph is pushed through the full
//! pipeline under all execution modes and checked against the reference
//! interpreter. This is the repo's mini-proptest (the vendored registry
//! has no proptest crate): failures print the generating seed, which is
//! sufficient to reproduce deterministically.

use disc::compiler::{CompileOptions, DiscCompiler, Mode};
use disc::dhlo::{BinKind, DType, ReduceKind, UnKind};
use disc::graph::{Edge, GOp, Graph, GraphBuilder};
use disc::runtime::reference::eval_module;
use disc::runtime::tensor::Tensor;
use disc::util::prng::Prng;

/// Generate a random well-typed graph over a `[?, width]` dataflow.
/// Returns the graph; inputs are a single dynamic-rows placeholder.
fn random_graph(seed: u64, width: usize) -> Graph {
    let mut rng = Prng::new(seed);
    let mut gb = GraphBuilder::new(format!("rand{seed}"));
    let x = gb.placeholder("x", DType::F32, &[-1, width as i64]);
    // Pool of values with shape [?, width].
    let mut pool: Vec<Edge> = vec![x];
    let n_ops = rng.range(3, 14);
    for i in 0..n_ops {
        let pick = *rng.choose(&pool);
        let choice = rng.below(10);
        let v = match choice {
            0 => gb.unary(&format!("t{i}"), UnKind::Tanh, pick),
            1 => gb.unary(&format!("g{i}"), UnKind::Gelu, pick),
            2 => gb.unary(&format!("r{i}"), UnKind::Relu, pick),
            3 => gb.unary(&format!("s{i}"), UnKind::Sigmoid, pick),
            4 => {
                let other = *rng.choose(&pool);
                gb.binary(&format!("a{i}"), BinKind::Add, pick, other)
            }
            5 => {
                let other = *rng.choose(&pool);
                gb.binary(&format!("m{i}"), BinKind::Mul, pick, other)
            }
            6 => gb.softmax(&format!("sm{i}"), pick),
            7 => {
                let gamma = gb.weight(&format!("ga{i}"), &[width], seed + i as u64);
                let beta = gb.weight(&format!("be{i}"), &[width], seed + 100 + i as u64);
                gb.layernorm(&format!("ln{i}"), pick, gamma, beta)
            }
            8 => {
                let w = gb.weight(&format!("w{i}"), &[width, width], seed + 200 + i as u64);
                gb.matmul(&format!("mm{i}"), pick, w)
            }
            _ => {
                let b = gb.weight(&format!("bw{i}"), &[width], seed + 300 + i as u64);
                gb.bias_add(&format!("ba{i}"), pick, b)
            }
        };
        pool.push(v);
    }
    // A reduction tail keeps outputs small and exercises input fusion.
    let last = *pool.last().unwrap();
    let red = gb.add("final_red", GOp::Reduce { kind: ReduceKind::Mean, axes: vec![1] }, &[last]);
    gb.finish(&[last, red])
}

fn run_all_modes_agree(seed: u64) {
    let width = 8 + 4 * (seed % 3) as usize;
    let g = random_graph(seed, width);
    let module = disc::bridge::lower(&g)
        .unwrap_or_else(|e| panic!("seed {seed}: lowering failed: {e:#}"));
    let compiler = DiscCompiler::new().unwrap();
    let mut rng = Prng::new(seed ^ 0xABCD);

    let mut models: Vec<(Mode, _)> = [Mode::Eager, Mode::VmNimble, Mode::Disc, Mode::Static]
        .into_iter()
        .map(|mode| {
            let m = disc::bridge::lower(&g).unwrap();
            (mode, compiler.compile(m, &CompileOptions::mode(mode)).unwrap())
        })
        .collect();

    for rows in [rng.range(2, 9), rng.range(10, 33)] {
        let input = Tensor::f32(&[rows, width], rng.fill_f32(rows * width, 1.0));
        let want = eval_module(&module, &[input.clone()])
            .unwrap_or_else(|e| panic!("seed {seed}: reference failed: {e:#}"));
        for (mode, model) in models.iter_mut() {
            let got = model
                .run(std::slice::from_ref(&input))
                .unwrap_or_else(|e| panic!("seed {seed} mode {mode:?}: run failed: {e:#}"));
            for (o, (g_t, w_t)) in got.outputs.iter().zip(&want.outputs).enumerate() {
                assert!(
                    g_t.allclose(w_t, 1e-3, 1e-3).unwrap(),
                    "seed {seed} mode {mode:?} rows {rows} output {o}: max diff {}",
                    g_t.max_abs_diff(w_t).unwrap_or(f32::NAN)
                );
            }
        }
    }
}

#[test]
fn property_all_modes_agree_on_random_graphs() {
    for seed in 0..12u64 {
        run_all_modes_agree(seed);
    }
}

#[test]
fn property_fusion_never_increases_kernel_count() {
    // The fusion plan's kernel count is never worse than unfused, for any
    // random graph.
    for seed in 100..130u64 {
        let g = random_graph(seed, 8);
        let m = disc::bridge::lower(&g).unwrap();
        let fused = disc::fusion::plan(&m, &disc::fusion::FusionOptions::default());
        let unfused_count = m.memory_intensive_count();
        assert!(
            fused.kernel_count(&m) <= unfused_count,
            "seed {seed}: fusion increased kernels"
        );
    }
}

#[test]
fn property_constraints_never_shrink_fusion_groups() {
    // Adding constraint knowledge can only merge more, never less.
    for seed in 200..230u64 {
        let g = random_graph(seed, 8);
        let m = disc::bridge::lower(&g).unwrap();
        let with = disc::fusion::plan(&m, &disc::fusion::FusionOptions::default());
        let without = disc::fusion::plan(
            &m,
            &disc::fusion::FusionOptions { use_constraints: false, ..Default::default() },
        );
        assert!(
            with.kernel_count(&m) <= without.kernel_count(&m),
            "seed {seed}: constraints hurt fusion"
        );
    }
}

#[test]
fn property_optimize_preserves_numerics() {
    for seed in 300..320u64 {
        let g = random_graph(seed, 8);
        let m = disc::bridge::lower(&g).unwrap();
        let opt = disc::passes::optimize(&m).unwrap();
        assert!(opt.instrs.len() <= m.instrs.len(), "seed {seed}: passes grew the module");
        let mut rng = Prng::new(seed);
        let rows = rng.range(2, 17);
        let input = Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0));
        let a = eval_module(&m, &[input.clone()]).unwrap();
        let b = eval_module(&opt, &[input]).unwrap();
        for (x, y) in a.outputs.iter().zip(&b.outputs) {
            assert!(
                x.allclose(y, 1e-6, 1e-6).unwrap(),
                "seed {seed}: optimization changed numerics"
            );
        }
    }
}

#[test]
fn property_cache_never_recompiles_repeated_shapes() {
    // Serving the same shape stream twice must not trigger new compiles —
    // the core DISC claim, over random graphs.
    let compiler = DiscCompiler::new().unwrap();
    for seed in 400..406u64 {
        let g = random_graph(seed, 8);
        let m = disc::bridge::lower(&g).unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(seed);
        let shapes: Vec<usize> = (0..4).map(|_| rng.range(2, 40)).collect();
        for &rows in &shapes {
            let input = Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0));
            model.run(&[input]).unwrap();
        }
        let misses = model.cache_stats().unwrap().misses;
        for &rows in &shapes {
            let input = Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0));
            model.run(&[input]).unwrap();
        }
        assert_eq!(
            model.cache_stats().unwrap().misses,
            misses,
            "seed {seed}: repeated shapes recompiled"
        );
    }
}

#[test]
fn property_buffer_liveness_is_sound() {
    // Programs with aggressive dealloc placement still produce outputs for
    // random graphs at random shapes (no use-after-free of value slots).
    let compiler = DiscCompiler::new().unwrap();
    for seed in 500..510u64 {
        let g = random_graph(seed, 12);
        let m = disc::bridge::lower(&g).unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(seed);
        for _ in 0..3 {
            let rows = rng.range(1, 50);
            let input = Tensor::f32(&[rows, 12], rng.fill_f32(rows * 12, 1.0));
            let out = model.run(&[input]).unwrap();
            assert!(!out.outputs.is_empty());
        }
    }
}

#[test]
fn plan_cache_bit_matches_interpreter_on_workloads() {
    // The launch-plan + device-resident replay tier must be bit-exact
    // against the uncached interpreter executor on real workloads, over a
    // stream that repeats every shape (so the second half replays plans).
    let compiler = DiscCompiler::new().unwrap();
    for name in ["bert", "seq2seq", "transformer"] {
        let w = disc::workloads::by_name(name).unwrap();
        let module = disc::bridge::lower(&w.graph).unwrap();
        let mut cached =
            compiler.compile(module, &CompileOptions::mode(Mode::Disc)).unwrap();
        let m2 = disc::bridge::lower(&w.graph).unwrap();
        let mut plain = compiler
            .compile(
                m2,
                &CompileOptions {
                    plan_cache: false,
                    device_resident: false,
                    ..CompileOptions::mode(Mode::Disc)
                },
            )
            .unwrap();
        let stream: Vec<_> = w
            .request_stream(4, 21)
            .into_iter()
            .chain(w.request_stream(4, 21))
            .collect();
        for inputs in stream {
            let a = cached.run(&inputs).unwrap();
            let b = plain.run(&inputs).unwrap();
            assert_eq!(
                a.outputs, b.outputs,
                "{name}: plan-cached outputs diverged from the interpreter path"
            );
        }
        let ps = cached.plan_stats().unwrap();
        assert!(ps.hits >= 4, "{name}: repeated shapes must replay plans (hits={})", ps.hits);
        assert_eq!(plain.plan_stats().unwrap().hits, 0);
    }
}

#[test]
fn weight_cache_uploads_gemm_weights_once_on_repeat_bindings() {
    // The tentpole claim: on a repeat-binding stream, GEMM weights are
    // uploaded exactly once per program — replays serve every weight from
    // the resident cache (weight_cache_hits > 0, zero misses) and move
    // strictly fewer h2d bytes than the recording run, while staying
    // bit-identical to the host-path interpreter.
    let compiler = DiscCompiler::new().unwrap();
    for name in ["transformer", "bert"] {
        let w = disc::workloads::by_name(name).unwrap();
        let module = disc::bridge::lower(&w.graph).unwrap();
        let mut cached = compiler.compile(module, &CompileOptions::mode(Mode::Disc)).unwrap();
        let m2 = disc::bridge::lower(&w.graph).unwrap();
        let mut plain = compiler
            .compile(
                m2,
                &CompileOptions {
                    plan_cache: false,
                    device_resident: false,
                    ..CompileOptions::mode(Mode::Disc)
                },
            )
            .unwrap();

        let mut rng = Prng::new(13);
        let inputs = (w.gen)(w.seq_range.0, &mut rng);

        let first = cached.run(&inputs).unwrap();
        assert!(
            first.metrics.weight_cache_misses > 0,
            "{name}: first request must upload weights"
        );
        assert!(first.metrics.weight_resident_bytes > 0, "{name}: weights resident");

        let second = cached.run(&inputs).unwrap();
        assert_eq!(second.metrics.plan_hits, 1, "{name}: repeat binding must replay");
        assert!(
            second.metrics.weight_cache_hits > 0,
            "{name}: replay must serve resident weights"
        );
        assert_eq!(
            second.metrics.weight_cache_misses, 0,
            "{name}: weights are uploaded exactly once"
        );
        assert!(
            second.metrics.h2d_bytes < first.metrics.h2d_bytes,
            "{name}: replay h2d {} must be strictly below recording h2d {}",
            second.metrics.h2d_bytes,
            first.metrics.h2d_bytes
        );

        // Dev→dev GEMM results are bit-identical to the host-path
        // interpreter — on the interpret/record tier and on replay.
        let reference = plain.run(&inputs).unwrap();
        assert_eq!(
            first.outputs, reference.outputs,
            "{name}: weight-cached interpret diverged from host path"
        );
        assert_eq!(
            second.outputs, reference.outputs,
            "{name}: device-chained replay diverged from host path"
        );

        // A different binding records a new plan but re-uses every weight.
        let other = (w.gen)(w.seq_range.0 + 3, &mut rng);
        let third = cached.run(&other).unwrap();
        assert_eq!(
            third.metrics.weight_cache_misses, 0,
            "{name}: weights are shared across bindings"
        );
    }
}

#[test]
fn kernel_store_shared_across_workers_compiles_once() {
    // M workers race one pattern×bucket: exactly one compile process-wide;
    // the other M-1 fetches are shared hits or single-flight dedup joins.
    use std::sync::{Arc, Barrier};

    const M: usize = 4;
    // tanh→add chain: fuses into exactly one kernel (a lone elementwise op
    // would be a singleton launch that never touches the kernel cache).
    let mut gb = GraphBuilder::new("one_kernel".to_string());
    let x = gb.placeholder("x", DType::F32, &[-1, 8]);
    let t = gb.unary("t", UnKind::Tanh, x);
    let a = gb.binary("a", BinKind::Add, t, x);
    let g = gb.finish(&[a]);
    let module = disc::bridge::lower(&g).unwrap();
    let compiler = DiscCompiler::new().unwrap();
    let model = compiler.compile(module, &CompileOptions::mode(Mode::Disc)).unwrap();
    let (prog, workers) = model.fork_workers(M).unwrap();

    let barrier = Arc::new(Barrier::new(M));
    let input = Tensor::f32(&[5, 8], vec![0.25; 40]);
    let handles: Vec<_> = workers
        .into_iter()
        .map(|mut exec| {
            let barrier = barrier.clone();
            let prog = prog.clone();
            let input = input.clone();
            std::thread::spawn(move || {
                barrier.wait();
                let out = exec.run(&prog, &[input]).unwrap();
                (exec, out.outputs)
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let total_misses: u64 = results.iter().map(|(e, _)| e.cache.stats.misses).sum();
    let total_shared: u64 = results
        .iter()
        .map(|(e, _)| e.cache.stats.shared_hits + e.cache.stats.dedup_hits)
        .sum();
    assert_eq!(total_misses, 1, "one pattern must compile exactly once across {M} workers");
    assert_eq!(total_shared, (M - 1) as u64, "every other worker shares the compile");
    let snap = compiler.kernel_store().snapshot();
    assert_eq!(snap.misses, 1);
    assert_eq!(snap.hits + snap.dedup_hits, (M - 1) as u64);
    // And all workers computed the same thing.
    for (_, outs) in &results[1..] {
        assert_eq!(outs, &results[0].1);
    }
}

#[test]
fn multi_worker_output_bit_matches_single_worker_interpreter() {
    // Transformer + BERT: M workers sharing kernel/weight stores, each
    // serving the same stream (twice, so the second half replays recorded
    // plans against shared-store kernels and shared cached weights), must
    // produce outputs bit-identical to the single-worker interpreter tier.
    // The shared store must also compile exactly as much as a single
    // worker would have.
    const M: usize = 3;
    for name in ["transformer", "bert"] {
        let w = disc::workloads::by_name(name).unwrap();
        let stream: Vec<_> = w
            .request_stream(3, 31)
            .into_iter()
            .chain(w.request_stream(3, 31))
            .collect();

        // Single-worker baseline: how many compiles does this stream need?
        let solo_compiler = DiscCompiler::new().unwrap();
        let mut solo = solo_compiler
            .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
            .unwrap();
        for inputs in &stream {
            solo.run(inputs).unwrap();
        }
        let solo_compiles = solo_compiler.kernel_store().snapshot().misses;

        // Reference: the plain interpreter path (no plans, host-resident).
        let mut plain = solo_compiler
            .compile(
                disc::bridge::lower(&w.graph).unwrap(),
                &CompileOptions {
                    plan_cache: false,
                    device_resident: false,
                    ..CompileOptions::mode(Mode::Disc)
                },
            )
            .unwrap();
        let want: Vec<_> = stream.iter().map(|i| plain.run(i).unwrap().outputs).collect();

        // M workers, each running the full stream concurrently.
        let compiler = DiscCompiler::new().unwrap();
        let model = compiler
            .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
            .unwrap();
        let (prog, workers) = model.fork_workers(M).unwrap();
        let stream = std::sync::Arc::new(stream);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|mut exec| {
                let prog = prog.clone();
                let stream = stream.clone();
                std::thread::spawn(move || {
                    let outs: Vec<_> =
                        stream.iter().map(|i| exec.run(&prog, i).unwrap().outputs).collect();
                    (exec, outs)
                })
            })
            .collect();
        for h in handles {
            let (exec, outs) = h.join().unwrap();
            for (got, expect) in outs.iter().zip(&want) {
                assert_eq!(got, expect, "{name}: multi-worker output diverged from interpreter");
            }
            assert!(exec.plan_stats.hits >= 3, "{name}: repeat bindings must replay per worker");
        }
        let snap = compiler.kernel_store().snapshot();
        assert_eq!(
            snap.misses, solo_compiles,
            "{name}: {M} workers must compile exactly what one worker compiles"
        );
        assert!(
            compiler.weight_store().resident_bytes() > 0,
            "{name}: shared weights resident across workers"
        );
    }
}

#[test]
fn burst_queue_delay_drops_with_workers() {
    // A saturating burst (the whole stream offered effectively at once):
    // p99 queue delay must drop when the worker pool grows, and total
    // throughput must rise — the multi-tenant scaling claim.
    use disc::coordinator::{serve_closed_loop, serve_open_loop, ServeOptions};

    // Wall-clock scaling needs real cores; on a single-core runner 4
    // workers buy nothing and the comparison below is meaningless.
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        eprintln!("skipping burst scaling test: single-core machine");
        return;
    }
    let w = disc::workloads::by_name("transformer").unwrap();
    let compiler = DiscCompiler::new().unwrap();
    // Interpret-only tier: forked workers and the model's own executor do
    // identical per-request work, so the only variable between the two
    // configurations below is queueing.
    let opts_interp = CompileOptions {
        plan_cache: false,
        device_resident: false,
        ..CompileOptions::mode(Mode::Disc)
    };
    let mut model =
        compiler.compile(disc::bridge::lower(&w.graph).unwrap(), &opts_interp).unwrap();
    // Warm the shared kernel store so both configurations serve compile-free.
    serve_closed_loop(&mut model, w.request_stream(32, 63)).unwrap();

    let serve = |model: &mut _, workers: usize| {
        let opts = ServeOptions::rate(50_000.0).workers(workers).bursty(8);
        serve_open_loop(model, w.request_stream(32, 63), &opts).unwrap()
    };
    // Wall-clock comparison on a shared CI machine: retry a couple of
    // times so one scheduling hiccup cannot fail the suite; the claim
    // itself (less queueing, more throughput with 4 workers draining a
    // saturating burst) holds by a ~4x margin in the expected case.
    let mut last = None;
    for attempt in 0..3 {
        let one = serve(&mut model, 1);
        let four = serve(&mut model, 4);
        assert_eq!(one.completed, 32);
        assert_eq!(four.completed, 32);
        // Steady state: no run waits on the compiler once the store is warm.
        assert_eq!(four.metrics.compile_events, 0, "warm store: no compiles under burst");
        if four.queue_p99 < one.queue_p99 && four.throughput_rps > one.throughput_rps {
            return;
        }
        eprintln!(
            "attempt {attempt}: queue_p99 1w={:?} 4w={:?}, rps 1w={:.1} 4w={:.1}",
            one.queue_p99, four.queue_p99, one.throughput_rps, four.throughput_rps
        );
        last = Some((one, four));
    }
    let (one, four) = last.unwrap();
    assert!(
        four.queue_p99 < one.queue_p99,
        "queue p99 must drop with workers: 1w={:?} 4w={:?}",
        one.queue_p99,
        four.queue_p99
    );
    assert!(
        four.throughput_rps > one.throughput_rps,
        "throughput must rise with workers: 1w={:.1} 4w={:.1}",
        one.throughput_rps,
        four.throughput_rps
    );
}

#[test]
fn serving_stream_matches_reference_for_every_workload() {
    // End-to-end: all seven Table-1 workloads, DISC vs reference, over a
    // short dynamic request stream.
    let compiler = DiscCompiler::new().unwrap();
    for w in disc::workloads::all() {
        let module = disc::bridge::lower(&w.graph).unwrap();
        let mut model =
            compiler.compile(module, &CompileOptions::mode(Mode::Disc)).unwrap();
        for inputs in w.request_stream(3, 7) {
            let got = model.run(&inputs).unwrap();
            let want = eval_module(model.module(), &inputs).unwrap();
            for (g_t, w_t) in got.outputs.iter().zip(&want.outputs) {
                assert!(
                    g_t.allclose(w_t, 1e-3, 1e-3).unwrap(),
                    "{}: compiled path diverged from reference",
                    w.name
                );
            }
        }
    }
}

#[test]
fn batched_serving_bit_matches_unbatched_on_transformer_and_bert() {
    // The cross-request batching acceptance gate: a bursty stream with
    // mixed sequence lengths, served by multiple workers with batching
    // on, must (a) actually coalesce (occupancy > 1, fewer dispatches
    // than requests) and (b) return outputs bit-identical to an
    // unbatched single-worker run of the same stream.
    use disc::coordinator::{serve_open_loop, ServeOptions};

    for name in ["transformer", "bert"] {
        let w = disc::workloads::by_name(name).unwrap();
        let stream = w.request_stream(10, 53);

        // Unbatched single-worker reference (direct runs, no coordinator).
        let compiler = DiscCompiler::new().unwrap();
        let mut reference = compiler
            .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
            .unwrap();
        let want: Vec<Vec<Tensor>> =
            stream.iter().map(|r| reference.run(r).unwrap().outputs).collect();

        // Batched, bursty, multi-worker. A flooding rate keeps the queue
        // deep while dispatches run; batch formation still depends on
        // scheduling, so retry a couple of times before declaring the
        // coalescing claim broken (outputs are checked on every attempt).
        let mut coalesced = None;
        for attempt in 0..3 {
            let compiler = DiscCompiler::new().unwrap();
            let mut model = compiler
                .compile(
                    disc::bridge::lower(&w.graph).unwrap(),
                    &CompileOptions::mode(Mode::Disc),
                )
                .unwrap();
            let opts = ServeOptions::rate(1_000_000.0)
                .workers(2)
                .bursty(stream.len())
                .batch(4)
                .batch_window_us(200)
                .keep_outputs();
            let report = serve_open_loop(&mut model, stream.clone(), &opts).unwrap();
            assert_eq!(report.completed, 10, "{name}: lost requests");
            assert_eq!(report.outputs.len(), 10, "{name}: missing captured outputs");
            for (id, got) in &report.outputs {
                assert_eq!(
                    got, &want[*id as usize],
                    "{name}: batched request {id} diverged from the unbatched run (attempt {attempt})"
                );
            }
            assert_eq!(
                report.per_worker.iter().map(|wr| wr.launches).sum::<usize>(),
                report.batch_launches,
                "{name}: per-worker launches must sum to the total"
            );
            assert_eq!(
                report.per_worker.iter().map(|wr| wr.completed).sum::<usize>(),
                10,
                "{name}: per-worker requests must sum to the stream"
            );
            if report.batch_occupancy > 1.0 {
                coalesced = Some(report);
                break;
            }
        }
        let report = coalesced
            .unwrap_or_else(|| panic!("{name}: bursty flood never coalesced in 3 attempts"));
        assert!(report.batch_launches < 10, "{name}: dispatches must undercut requests");
        assert!(report.batched_requests >= 2, "{name}: batched dispatches cover >= 2 requests");
        assert!(
            report.metrics.batched_launches >= 1,
            "{name}: executor must record batched dispatches"
        );
    }
}

#[test]
fn batching_edge_cases_fall_back_to_solo() {
    use disc::coordinator::{serve_open_loop, ServeOptions};

    // max_batch == 1 is exactly the pre-batching behavior.
    let w = disc::workloads::by_name("transformer").unwrap();
    let compiler = DiscCompiler::new().unwrap();
    let mut model = compiler
        .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
        .unwrap();
    let stream = w.request_stream(5, 59);
    let report = serve_open_loop(
        &mut model,
        stream.clone(),
        &ServeOptions::rate(100_000.0).batch(1).keep_outputs(),
    )
    .unwrap();
    assert_eq!(report.completed, 5);
    assert_eq!(report.batch_launches, 5);
    assert_eq!(report.batched_requests, 0);
    assert_eq!(report.batch_occupancy, 1.0);

    // A trickle under a tiny window: every dispatch may end up solo, but
    // the stream must complete with correct outputs either way.
    let report2 = serve_open_loop(
        &mut model,
        stream.clone(),
        &ServeOptions::rate(400.0).batch(4).batch_window_us(50).keep_outputs(),
    )
    .unwrap();
    assert_eq!(report2.completed, 5);
    assert!(report2.batch_launches <= 5);
    assert!(report2.batch_occupancy >= 1.0);
    for ((id, got), (_, want)) in report2.outputs.iter().zip(&report.outputs) {
        assert_eq!(got, want, "request {id} diverged between batching configs");
    }

    // max_batch larger than the whole stream: bounded by what is queued.
    let report3 = serve_open_loop(
        &mut model,
        stream,
        &ServeOptions::rate(1_000_000.0).bursty(5).batch(64).keep_outputs(),
    )
    .unwrap();
    assert_eq!(report3.completed, 5);
    for ((id, got), (_, want)) in report3.outputs.iter().zip(&report.outputs) {
        assert_eq!(got, want, "request {id} diverged under an oversized max_batch");
    }

    // Baseline backends never batch but still serve (single worker).
    let mut eager = compiler
        .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Eager))
        .unwrap();
    let report4 = serve_open_loop(
        &mut eager,
        w.request_stream(3, 61),
        &ServeOptions::rate(50_000.0).batch(4),
    )
    .unwrap();
    assert_eq!(report4.completed, 3);
    assert_eq!(report4.batch_launches, 3, "eager backend dispatches solo");
    assert_eq!(report4.batched_requests, 0);
}

#[test]
fn batched_plan_replay_bit_matches_solo_interpret_on_transformer_and_bert() {
    // The batched-plan acceptance gate: repeat same-shape groups must
    // replay a recorded batch plan (one record, then hits; zero
    // re-analysis) with per-request outputs bit-identical to solo
    // interpret runs of the same requests.
    for name in ["transformer", "bert"] {
        let w = disc::workloads::by_name(name).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler
            .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
            .unwrap();
        // Solo interpret reference: no plan caches, host-resident.
        let mut ref_opts = CompileOptions::mode(Mode::Disc);
        ref_opts.plan_cache = false;
        ref_opts.device_resident = false;
        let mut reference =
            compiler.compile(disc::bridge::lower(&w.graph).unwrap(), &ref_opts).unwrap();

        let mut rng = Prng::new(67);
        let lens = [6usize, 9, 12];
        for round in 0..3 {
            // Same group shape every round, fresh request contents.
            let group: Vec<Vec<Tensor>> = lens.iter().map(|&s| (w.gen)(s, &mut rng)).collect();
            let out = model.run_batch(&group).unwrap();
            assert_eq!(out.metrics.batched_launches, 1, "{name}: group must stack");
            if round == 0 {
                assert_eq!(out.metrics.batch_plan_misses, 1, "{name}: first dispatch records");
                assert_eq!(out.metrics.batch_plan_hits, 0);
            } else {
                assert_eq!(
                    out.metrics.batch_plan_hits, 1,
                    "{name}: repeat shape must replay (round {round})"
                );
                assert_eq!(out.metrics.batch_plan_misses, 0);
            }
            for (r, got) in group.iter().zip(&out.outputs) {
                let want = reference.run(r).unwrap().outputs;
                assert_eq!(
                    got, &want,
                    "{name}: batched outputs diverged from solo interpret (round {round})"
                );
            }
        }
        let stats = model.batch_plan_stats().unwrap();
        assert_eq!(stats.misses, 1, "{name}: exactly one record");
        assert_eq!(stats.hits, 2, "{name}: every repeat replayed");
        assert_eq!(stats.entries, 1);

        // A permuted arrival order of the same shapes still replays (the
        // key sorts member extents) and keeps outputs member-aligned.
        let group: Vec<Vec<Tensor>> =
            [12usize, 6, 9].iter().map(|&s| (w.gen)(s, &mut rng)).collect();
        let out = model.run_batch(&group).unwrap();
        assert_eq!(out.metrics.batch_plan_hits, 1, "{name}: permuted group must hit");
        for (r, got) in group.iter().zip(&out.outputs) {
            assert_eq!(got, &reference.run(r).unwrap().outputs, "{name}: permuted diverged");
        }
    }
}

#[test]
fn bursty_batched_serving_replays_group_plans() {
    // Open-loop flood of a repeating length pattern: once the first group
    // of a shape records its plan, group-key-aware assembly steers later
    // bursts back to that shape and the executor replays it. Formation
    // depends on queue depth at dispatch time, so retry a few times
    // before declaring a regression; outputs must bit-match an unbatched
    // reference in every attempt.
    use disc::coordinator::{serve_open_loop, ServeOptions};
    let w = disc::workloads::by_name("transformer").unwrap();
    let compiler = DiscCompiler::new().unwrap();
    let lens = [6usize, 9, 12];
    let mut rng = Prng::new(71);
    let stream: Vec<Vec<Tensor>> =
        (0..24).map(|i| (w.gen)(lens[i % lens.len()], &mut rng)).collect();

    let mut reference = compiler
        .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
        .unwrap();
    let want: Vec<Vec<Tensor>> =
        stream.iter().map(|r| reference.run(r).unwrap().outputs).collect();

    let mut replayed = false;
    for attempt in 0..3 {
        let mut model = compiler
            .compile(disc::bridge::lower(&w.graph).unwrap(), &CompileOptions::mode(Mode::Disc))
            .unwrap();
        let report = serve_open_loop(
            &mut model,
            stream.clone(),
            &ServeOptions::rate(1_000_000.0)
                .bursty(stream.len())
                .batch(lens.len())
                .batch_window_us(200)
                .keep_outputs(),
        )
        .unwrap();
        assert_eq!(report.completed, stream.len());
        for (id, got) in &report.outputs {
            assert_eq!(
                got, &want[*id as usize],
                "request {id} diverged under batched serving (attempt {attempt})"
            );
        }
        if report.metrics.batch_plan_hits > 0 {
            replayed = true;
            break;
        }
    }
    assert!(replayed, "repeat same-shape bursts never replayed a batch plan in 3 attempts");
}
