//! Differential property harness: the runtime's execution tiers must be
//! **bit-exact**, not merely close.
//!
//! The repo's layered runtime (interpret → record → replay, solo → stacked
//! batch, single step → decode loop) is only safe to mix-and-match in the
//! serving coordinator because every tier computes the identical floats.
//! This harness locks that invariant down property-style: seeded random
//! binding/length streams (per-case seeds derived with `splitmix64`, the
//! same primitive the fault injector uses — no external PRNG crates) are
//! pushed through
//!
//!   * a **solo interpreter** model (plan cache and device residency off:
//!     every run walks the program from host buffers),
//!   * a **solo replay** model (first run records the launch plan, second
//!     run replays it),
//!   * a **batched replay** model (groups dispatched twice through
//!     `run_batch`, so the second round replays recorded batch plans), and
//!   * for the decode workload, the **step-loop tiers** (`run_decode`
//!     tiered vs interpret-only) and the continuous-batching scheduler
//!     (`serve_decode` with staggered mid-flight joins),
//!
//! and every output is compared with `assert_eq!` — bit-for-bit. Failures
//! print the generating case seed, which reproduces deterministically.

use disc::compiler::{CompileOptions, CompiledModel, DiscCompiler, Mode};
use disc::coordinator::decode::{serve_decode, DecodeJob, DecodeServeOptions};
use disc::runtime::faults::splitmix64;
use disc::runtime::tensor::Tensor;
use disc::util::prng::Prng;
use disc::workloads;

/// Compile a fresh model of `name` under `opts` (its own plan caches and
/// arena — tiers must agree *across* independent engines).
fn fresh_model(name: &str, opts: &CompileOptions) -> CompiledModel {
    let w = workloads::by_name(name).unwrap();
    let m = disc::bridge::lower(&w.graph).unwrap();
    let compiler = DiscCompiler::new().unwrap();
    compiler.compile(m, opts).unwrap()
}

/// Disc-mode options with the replay tiers disabled: every run is a pure
/// interpret/record-free walk (tier 1).
fn interpret_only() -> CompileOptions {
    let mut o = CompileOptions::mode(Mode::Disc);
    o.plan_cache = false;
    o.device_resident = false;
    o
}

/// Derive the next case seed from the stream state.
fn next_seed(state: &mut u64) -> u64 {
    *state = splitmix64(*state);
    *state
}

#[test]
fn replay_tiers_are_bit_exact_under_random_binding_streams() {
    for name in ["transformer", "bert", "seq2seq"] {
        let w = workloads::by_name(name).unwrap();
        let mut state = 0x5EED_0000 ^ name.len() as u64;
        // Small extents keep `cargo test -q` quick; variety in the stream
        // (repeats included) is what exercises record vs replay.
        let cases: Vec<(u64, Vec<Tensor>)> = (0..6)
            .map(|_| {
                let seed = next_seed(&mut state);
                let mut rng = Prng::new(seed);
                let seq = rng.range(w.seq_range.0, w.seq_range.0 + 6);
                (seed, (w.gen)(seq, &mut rng))
            })
            .collect();

        let mut interp = fresh_model(name, &interpret_only());
        let mut replay = fresh_model(name, &CompileOptions::mode(Mode::Disc));
        let mut batched = fresh_model(name, &CompileOptions::mode(Mode::Disc));

        // Ground truth: the pure interpreter tier.
        let want: Vec<Vec<Tensor>> = cases
            .iter()
            .map(|(seed, inputs)| {
                interp
                    .run(inputs)
                    .unwrap_or_else(|e| panic!("seed {seed} [{name}]: interpret run: {e:#}"))
                    .outputs
            })
            .collect();

        // Solo record then solo replay: both must match the interpreter.
        for ((seed, inputs), want) in cases.iter().zip(&want) {
            let first = replay.run(inputs).unwrap().outputs;
            assert_eq!(&first, want, "seed {seed} [{name}]: record tier diverged");
            let second = replay.run(inputs).unwrap().outputs;
            assert_eq!(&second, want, "seed {seed} [{name}]: replay tier diverged");
        }
        let ps = replay.plan_stats().expect("disc mode has a plan cache");
        assert!(ps.hits > 0, "[{name}]: second runs must replay recorded plans");

        // Batched replay: groups of 3, dispatched twice — the first round
        // records batch plans, the second replays them. Per-member outputs
        // must still be bit-identical to the solo interpreter.
        let groups: Vec<&[(u64, Vec<Tensor>)]> = cases.chunks(3).collect();
        for round in 0..2 {
            for (gi, group) in groups.iter().enumerate() {
                let inputs: Vec<Vec<Tensor>> =
                    group.iter().map(|(_, i)| i.clone()).collect();
                let out = batched.run_batch(&inputs).unwrap();
                for (k, (seed, _)) in group.iter().enumerate() {
                    assert_eq!(
                        out.outputs[k], want[gi * 3 + k],
                        "seed {seed} [{name}]: batched replay (round {round}) diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn memory_planner_is_bit_exact_against_planner_off() {
    // The symbolic memory planner changes *where* replay buffers live (one
    // planned arena extent with shared slots vs a lease per buffer), never
    // what they hold. Planner-on and planner-off engines must agree
    // bit-for-bit on every tier that replays: solo, stacked batch, decode.
    let planner_off = || {
        let mut o = CompileOptions::mode(Mode::Disc);
        o.runtime.memory_plan = false;
        o
    };

    for name in ["transformer", "bert"] {
        let w = workloads::by_name(name).unwrap();
        let mut state = 0x3E3_9_9A7 ^ name.len() as u64;
        let cases: Vec<(u64, Vec<Tensor>)> = (0..6)
            .map(|_| {
                let seed = next_seed(&mut state);
                let mut rng = Prng::new(seed);
                let seq = rng.range(w.seq_range.0, w.seq_range.0 + 6);
                (seed, (w.gen)(seq, &mut rng))
            })
            .collect();

        let mut on = fresh_model(name, &CompileOptions::mode(Mode::Disc));
        let mut off = fresh_model(name, &planner_off());
        // Two passes: the first records plans on both sides, the second
        // replays them — the pass where the planner actually runs.
        for round in 0..2 {
            for (seed, inputs) in &cases {
                let a = on.run(inputs).unwrap().outputs;
                let b = off.run(inputs).unwrap().outputs;
                assert_eq!(
                    a, b,
                    "seed {seed} [{name}]: planner-on solo run (round {round}) diverged"
                );
            }
            let groups: Vec<Vec<Vec<Tensor>>> = cases
                .chunks(3)
                .map(|g| g.iter().map(|(_, i)| i.clone()).collect())
                .collect();
            for group in &groups {
                let a = on.run_batch(group).unwrap().outputs;
                let b = off.run_batch(group).unwrap().outputs;
                assert_eq!(a, b, "[{name}]: planner-on batched dispatch (round {round}) diverged");
            }
        }
    }

    // Decode: the step loop's activations replay under the planner while
    // the KV slab stays a planner-owned long-lived residency — token
    // streams and probability rows must not move.
    let spec = workloads::decode::spec();
    let vocab = workloads::decode::VOCAB as i64;
    let mut state = 0x9_1A2_DEC0u64;
    let mut on = fresh_model("decode", &CompileOptions::mode(Mode::Disc));
    let mut off = fresh_model("decode", &planner_off());
    for _ in 0..3 {
        let seed = next_seed(&mut state);
        let mut rng = Prng::new(seed);
        let plen = rng.range(1, 4);
        let prompt = rng.fill_i64(plen, 0, vocab - 1);
        let gen_steps = rng.range(4, 10);
        let a = on.run_decode(&spec, &prompt, gen_steps).unwrap();
        let b = off.run_decode(&spec, &prompt, gen_steps).unwrap();
        assert_eq!(a.generated, b.generated, "seed {seed}: planner-on decode tokens diverged");
        assert_eq!(a.step_probs, b.step_probs, "seed {seed}: planner-on decode probs diverged");
    }
}

#[test]
fn multi_tenant_mix_is_bit_exact_per_tenant() {
    use disc::coordinator::tenants::{serve_mix, MixOptions, TenantSpec};

    // Each tenant's outputs from the shared-pool mix must be bit-identical
    // to that tenant served solo — sharing a worker pool, kernel store,
    // and weight store is invisible in the floats. Request ids are stream
    // indices, so `outputs` (id-sorted) aligns with the solo stream.
    let tenants: [(&str, &str, u64); 2] = [("lat", "transformer", 0xA11CE), ("thr", "bert", 0xB0B)];
    let n = 8;
    let want: Vec<Vec<Vec<Tensor>>> = tenants
        .iter()
        .map(|(_, wl, seed)| {
            let w = workloads::by_name(wl).unwrap();
            let mut interp = fresh_model(wl, &interpret_only());
            w.request_stream(n, *seed)
                .iter()
                .map(|inputs| {
                    interp
                        .run(inputs)
                        .unwrap_or_else(|e| panic!("[{wl}] solo interpret run: {e:#}"))
                        .outputs
                })
                .collect()
        })
        .collect();

    let specs = vec![
        TenantSpec::latency(tenants[0].0, tenants[0].1).requests(n).rate(600.0).seed(tenants[0].2),
        TenantSpec::throughput(tenants[1].0, tenants[1].1)
            .requests(n)
            .rate(900.0)
            .seed(tenants[1].2),
    ];
    let report =
        serve_mix(specs, &MixOptions::new().workers(2).batch(3).keep_outputs()).unwrap();
    for (t, tr) in report.tenants.iter().enumerate() {
        assert_eq!(tr.report.completed, n, "tenant {} must complete its stream", tr.name);
        assert_eq!(tr.report.outputs.len(), n, "tenant {} must capture every output", tr.name);
        for (id, got) in &tr.report.outputs {
            assert_eq!(
                got, &want[t][*id as usize],
                "tenant {} request {id} diverged from its solo run",
                tr.name
            );
        }
    }
}

#[test]
fn rebucket_epoch_flip_is_bit_exact_with_zero_stall_and_plan_retirement() {
    use std::collections::BTreeSet;
    use std::time::Duration;

    // A Zipf-skewed length stream (the traffic shape adaptive bucketing
    // exists for) pushed through an epoch flip on every tier: solo,
    // stacked batch, decode. Outputs must stay bit-identical to the pure
    // interpreter, the flip must cost zero compile stall (the candidate
    // family pre-compiles before the swap), and stale-epoch launch plans
    // must FIFO-retire from a bounded plan cache.
    let seed = 0x2EB0_5EEDu64;
    let w = workloads::by_name("transformer").unwrap();
    let lengths =
        disc::bench::zipf_lengths(seed, 10, w.seq_range.0 + 1, w.seq_range.0 + 30, 1.1);
    let distinct: BTreeSet<usize> = lengths.iter().copied().collect();
    let d = distinct.len();
    let mut rng = Prng::new(seed ^ 1);
    let cases: Vec<Vec<Tensor>> = lengths.iter().map(|&l| (w.gen)(l, &mut rng)).collect();

    // Ground truth: the pure interpreter tier.
    let mut interp = fresh_model("transformer", &interpret_only());
    let want: Vec<Vec<Tensor>> = cases
        .iter()
        .map(|inputs| {
            interp
                .run(inputs)
                .unwrap_or_else(|e| panic!("seed {seed:#x}: interpret run: {e:#}"))
                .outputs
        })
        .collect();

    // --- solo: warm, flip, replay under the new epoch -------------------
    // The plan cache is clamped to the distinct-binding count, so the
    // post-flip re-records (new PlanKey epoch) can only fit by evicting
    // every stale-epoch plan.
    let mut solo = fresh_model("transformer", &CompileOptions::mode(Mode::Disc));
    solo.set_max_plans(d);
    for (inputs, want) in cases.iter().zip(&want) {
        let out = solo.run(inputs).unwrap();
        assert_eq!(&out.outputs, want, "seed {seed:#x}: solo warm diverged");
    }
    let ps = solo.plan_stats().unwrap();
    assert_eq!(ps.entries, d, "seed {seed:#x}: warm phase must record {d} plans");
    let misses_before = ps.misses;

    let swapped = solo.rebucket_now(4).unwrap();
    assert!(swapped, "seed {seed:#x}: zipf traffic must derive a non-trivial policy");
    let mut stall = Duration::ZERO;
    for (inputs, want) in cases.iter().zip(&want) {
        let out = solo.run(inputs).unwrap();
        stall += out.metrics.compile_stall;
        assert_eq!(&out.outputs, want, "seed {seed:#x}: solo post-flip diverged");
    }
    assert_eq!(
        stall,
        Duration::ZERO,
        "seed {seed:#x}: post-flip solo dispatches stalled on compilation"
    );
    let ps = solo.plan_stats().unwrap();
    assert_eq!(
        ps.entries, d,
        "seed {seed:#x}: stale-epoch plans must retire as new-epoch plans record"
    );
    assert_eq!(
        ps.misses,
        misses_before + d as u64,
        "seed {seed:#x}: every distinct binding re-records once under the new epoch"
    );
    // Steady state: the new-epoch plans now replay.
    let hits_before = ps.hits;
    for (inputs, want) in cases.iter().zip(&want) {
        let out = solo.run(inputs).unwrap();
        assert_eq!(&out.outputs, want, "seed {seed:#x}: solo steady-state diverged");
    }
    assert!(
        solo.plan_stats().unwrap().hits > hits_before,
        "seed {seed:#x}: new-epoch plans must replay after the flip"
    );

    // --- stacked batch across the flip ----------------------------------
    let mut batched = fresh_model("transformer", &CompileOptions::mode(Mode::Disc));
    let groups: Vec<Vec<Vec<Tensor>>> =
        cases.chunks(2).map(|g| g.to_vec()).collect();
    let check_rounds = |batched: &mut CompiledModel, label: &str| -> Duration {
        let mut stall = Duration::ZERO;
        for round in 0..2 {
            for (gi, group) in groups.iter().enumerate() {
                let out = batched.run_batch(group).unwrap();
                stall += out.metrics.compile_stall;
                for (k, got) in out.outputs.iter().enumerate() {
                    assert_eq!(
                        got, &want[gi * 2 + k],
                        "seed {seed:#x}: batched {label} (round {round}) diverged"
                    );
                }
            }
        }
        stall
    };
    let _ = check_rounds(&mut batched, "pre-flip");
    assert!(batched.rebucket_now(4).unwrap(), "seed {seed:#x}: batched flip");
    let stall = check_rounds(&mut batched, "post-flip");
    assert_eq!(
        stall,
        Duration::ZERO,
        "seed {seed:#x}: post-flip batched dispatches stalled on compilation"
    );
    let bs = batched.batch_plan_stats().unwrap();
    assert!(bs.hits > 0, "seed {seed:#x}: new-epoch batch plans must replay");

    // --- decode across the flip ------------------------------------------
    let spec = workloads::decode::spec();
    let vocab = workloads::decode::VOCAB as i64;
    let mut drng = Prng::new(seed ^ 2);
    let prompt = drng.fill_i64(3, 0, vocab - 1);
    let mut dinterp = fresh_model("decode", &interpret_only());
    let dwant = dinterp.run_decode(&spec, &prompt, 8).unwrap();
    let mut tiered = fresh_model("decode", &CompileOptions::mode(Mode::Disc));
    let pre = tiered.run_decode(&spec, &prompt, 8).unwrap();
    assert_eq!(pre.generated, dwant.generated, "seed {seed:#x}: decode pre-flip tokens");
    tiered.rebucket_now(4).unwrap();
    let post = tiered.run_decode(&spec, &prompt, 8).unwrap();
    assert_eq!(post.generated, dwant.generated, "seed {seed:#x}: decode post-flip tokens");
    assert_eq!(post.step_probs, dwant.step_probs, "seed {seed:#x}: decode post-flip probs");
}

#[test]
fn decode_loops_are_bit_exact_across_tiers_and_scheduling() {
    let spec = workloads::decode::spec();
    let vocab = workloads::decode::VOCAB as i64;
    let mut state = 0xD1FF_DEC0_DEu64;
    let jobs: Vec<(u64, Vec<i64>, usize)> = (0..4)
        .map(|_| {
            let seed = next_seed(&mut state);
            let mut rng = Prng::new(seed);
            let plen = rng.range(1, 4);
            let gen_steps = rng.range(4, 10);
            (seed, rng.fill_i64(plen, 0, vocab - 1), gen_steps)
        })
        .collect();

    // Ground truth: the tiered solo step loop (records, then replays one
    // plan family per bucket).
    let mut tiered = fresh_model("decode", &CompileOptions::mode(Mode::Disc));
    let want: Vec<disc::runtime::executor::DecodeOutput> = jobs
        .iter()
        .map(|(seed, prompt, gen)| {
            tiered
                .run_decode(&spec, prompt, *gen)
                .unwrap_or_else(|e| panic!("seed {seed}: tiered decode: {e:#}"))
        })
        .collect();

    // Interpret-only step loop: no plans recorded or replayed at all.
    let mut interp = fresh_model("decode", &interpret_only());
    for ((seed, prompt, gen), want) in jobs.iter().zip(&want) {
        let out = interp.run_decode(&spec, prompt, *gen).unwrap();
        assert_eq!(out.generated, want.generated, "seed {seed}: interpret decode tokens");
        assert_eq!(out.step_probs, want.step_probs, "seed {seed}: interpret decode probs");
    }

    // Continuous batching with staggered mid-flight joins: every job's
    // step stream must be bit-identical to its solo loop even though its
    // steps ran stacked with whatever else occupied the batch.
    let mut served = fresh_model("decode", &CompileOptions::mode(Mode::Disc));
    let djobs: Vec<DecodeJob> = jobs
        .iter()
        .enumerate()
        .map(|(i, (_, prompt, gen))| DecodeJob {
            id: i as u64,
            prompt: prompt.clone(),
            gen_steps: *gen,
            arrive_step: i as u64 * 2,
        })
        .collect();
    let report =
        serve_decode(&mut served, &spec, djobs, &DecodeServeOptions::batch(3).keep_probs())
            .unwrap();
    assert_eq!(report.completed.len(), jobs.len());
    assert!(report.joins >= 1, "staggered arrivals must exercise mid-flight joins");
    for c in &report.completed {
        let (seed, _, _) = jobs[c.id as usize];
        let want = &want[c.id as usize];
        assert_eq!(c.generated, want.generated, "seed {seed}: scheduled decode tokens");
        let probs = c.probs.as_ref().expect("captured");
        assert_eq!(probs, &want.step_probs, "seed {seed}: scheduled decode probs");
    }
}
