//! Symbolic shape machinery — the paper's §4.2.1 "adaptive shape inference".
//!
//! Two stages, exactly as DISC describes:
//!
//! 1. **Compile time**: dynamic dimensions are *symbols* ([`SymId`]) carried
//!    in tensor types. A union-find over symbols records *dimension-size
//!    equality* constraints, and a second union-find over IR values records
//!    *tensor-size equality* constraints. Constraints come from op semantics
//!    (`Transpose` preserves element count, `Add` preserves shape, …) and
//!    from hints injected by the framework bridge (e.g. `tf.Split` outputs
//!    share a shape — information that is otherwise lost after lowering).
//!
//! 2. **Runtime**: every symbol has a [`ShapeExpr`] definition; the compiler
//!    emits a host-side *shape calculation program* (see `program::shapegen`)
//!    that evaluates the expressions against the actual input shapes of each
//!    request. Data-dependent dims (`Unique`) are filled in by the kernel
//!    that produces them.

pub mod sym;

pub use sym::{Dim, ShapeExpr, SymId, SymbolTable};
