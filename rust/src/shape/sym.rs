//! Symbolic dimensions, shape expressions and the constraint store.

use std::collections::HashMap;
use std::fmt;

/// Identifier of a symbolic dimension. Symbols are allocated by the
/// [`SymbolTable`] owned by a `dhlo::Module`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SymId(pub u32);

impl fmt::Display for SymId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// One dimension of a tensor type: statically known or symbolic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    Fixed(usize),
    Sym(SymId),
}

impl Dim {
    pub fn fixed(&self) -> Option<usize> {
        match self {
            Dim::Fixed(n) => Some(*n),
            Dim::Sym(_) => None,
        }
    }
    pub fn is_dynamic(&self) -> bool {
        matches!(self, Dim::Sym(_))
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dim::Fixed(n) => write!(f, "{n}"),
            Dim::Sym(s) => write!(f, "{s}"),
        }
    }
}

/// How a symbolic dimension's concrete value is obtained at runtime.
///
/// These expressions are what the compile-time-generated *shape calculation*
/// code evaluates on the host per incoming request (§4.2.1 "shape
/// calculation"). They form a small arithmetic language over input dims,
/// other symbols, and elements of (host-resident) shape tensors.
#[derive(Debug, Clone, PartialEq)]
pub enum ShapeExpr {
    /// Constant (used when a symbol gets refined to a known value).
    Const(i64),
    /// The extent of axis `axis` of entry-parameter `param`.
    InputDim { param: usize, axis: usize },
    /// The value of another dimension (fixed or symbolic).
    Dim(Dim),
    /// The `index`-th element of the i64 tensor produced by IR value
    /// `value` (e.g. the `start_indices` operand of a `DSlice`). The
    /// executor evaluates such tensors on the host.
    Elem { value: usize, index: usize },
    /// Data-dependent extent produced by the kernel computing IR value
    /// `value` (e.g. the output length of `Unique`). Filled in after that
    /// kernel runs.
    DataDep { value: usize },
    Add(Box<ShapeExpr>, Box<ShapeExpr>),
    Sub(Box<ShapeExpr>, Box<ShapeExpr>),
    Mul(Box<ShapeExpr>, Box<ShapeExpr>),
    /// Ceil-division, for strided slices.
    CeilDiv(Box<ShapeExpr>, Box<ShapeExpr>),
    Max(Box<ShapeExpr>, Box<ShapeExpr>),
}

impl ShapeExpr {
    pub fn add(a: ShapeExpr, b: ShapeExpr) -> ShapeExpr {
        ShapeExpr::Add(Box::new(a), Box::new(b))
    }
    pub fn sub(a: ShapeExpr, b: ShapeExpr) -> ShapeExpr {
        ShapeExpr::Sub(Box::new(a), Box::new(b))
    }
    pub fn mul(a: ShapeExpr, b: ShapeExpr) -> ShapeExpr {
        ShapeExpr::Mul(Box::new(a), Box::new(b))
    }
    pub fn ceil_div(a: ShapeExpr, b: ShapeExpr) -> ShapeExpr {
        ShapeExpr::CeilDiv(Box::new(a), Box::new(b))
    }
    pub fn max(a: ShapeExpr, b: ShapeExpr) -> ShapeExpr {
        ShapeExpr::Max(Box::new(a), Box::new(b))
    }

    /// Symbols this expression reads (for topological ordering of the shape
    /// calculation program).
    pub fn deps(&self, out: &mut Vec<SymId>) {
        match self {
            ShapeExpr::Dim(Dim::Sym(s)) => out.push(*s),
            ShapeExpr::Add(a, b)
            | ShapeExpr::Sub(a, b)
            | ShapeExpr::Mul(a, b)
            | ShapeExpr::CeilDiv(a, b)
            | ShapeExpr::Max(a, b) => {
                a.deps(out);
                b.deps(out);
            }
            _ => {}
        }
    }

    /// IR values whose *contents* this expression reads.
    pub fn value_deps(&self, out: &mut Vec<usize>) {
        match self {
            ShapeExpr::Elem { value, .. } | ShapeExpr::DataDep { value } => out.push(*value),
            ShapeExpr::Add(a, b)
            | ShapeExpr::Sub(a, b)
            | ShapeExpr::Mul(a, b)
            | ShapeExpr::CeilDiv(a, b)
            | ShapeExpr::Max(a, b) => {
                a.value_deps(out);
                b.value_deps(out);
            }
            _ => {}
        }
    }
}

impl fmt::Display for ShapeExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShapeExpr::Const(c) => write!(f, "{c}"),
            ShapeExpr::InputDim { param, axis } => write!(f, "arg{param}.dim{axis}"),
            ShapeExpr::Dim(d) => write!(f, "{d}"),
            ShapeExpr::Elem { value, index } => write!(f, "%{value}[{index}]"),
            ShapeExpr::DataDep { value } => write!(f, "datadep(%{value})"),
            ShapeExpr::Add(a, b) => write!(f, "({a} + {b})"),
            ShapeExpr::Sub(a, b) => write!(f, "({a} - {b})"),
            ShapeExpr::Mul(a, b) => write!(f, "({a} * {b})"),
            ShapeExpr::CeilDiv(a, b) => write!(f, "ceildiv({a}, {b})"),
            ShapeExpr::Max(a, b) => write!(f, "max({a}, {b})"),
        }
    }
}

#[derive(Debug, Clone)]
struct SymInfo {
    def: ShapeExpr,
    name: String,
}

/// Symbol store + the two constraint families of §4.2.1.
///
/// *Dimension-size equality* is a union-find over [`SymId`]: `unify(a, b)`
/// records that two symbolic dims always carry the same runtime extent;
/// `canon` returns the representative used by fusion and codegen when they
/// compare shapes without knowing values.
///
/// *Tensor-size equality* is a union-find over IR value ids: two tensors in
/// the same class are guaranteed to hold the same number of elements even
/// when their dim vectors differ (e.g. across `Reshape`).
#[derive(Debug, Clone, Default)]
pub struct SymbolTable {
    syms: Vec<SymInfo>,
    parent: Vec<u32>,
    /// value-id → size-class parent (lazily sized).
    size_parent: HashMap<usize, usize>,
}

impl SymbolTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.syms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.syms.is_empty()
    }

    /// Allocate a fresh symbol with a definition and a debug name.
    pub fn fresh(&mut self, name: impl Into<String>, def: ShapeExpr) -> SymId {
        let id = SymId(self.syms.len() as u32);
        self.syms.push(SymInfo { def, name: name.into() });
        self.parent.push(id.0);
        id
    }

    pub fn def(&self, s: SymId) -> &ShapeExpr {
        &self.syms[s.0 as usize].def
    }

    pub fn name(&self, s: SymId) -> &str {
        &self.syms[s.0 as usize].name
    }

    /// Representative of the dimension-equality class of `s`.
    pub fn canon(&self, s: SymId) -> SymId {
        let mut cur = s.0;
        while self.parent[cur as usize] != cur {
            cur = self.parent[cur as usize];
        }
        SymId(cur)
    }

    /// Record a dimension-size equality constraint.
    pub fn unify(&mut self, a: SymId, b: SymId) {
        let (ra, rb) = (self.canon(a), self.canon(b));
        if ra != rb {
            // A constant-defined root wins (so refined symbols collapse to
            // `Fixed` in `canon_dim`); otherwise union by smaller id so
            // representatives are stable across runs.
            let a_const = matches!(self.def(ra), ShapeExpr::Const(_));
            let b_const = matches!(self.def(rb), ShapeExpr::Const(_));
            let (winner, loser) = match (a_const, b_const) {
                (true, false) => (ra, rb),
                (false, true) => (rb, ra),
                _ => {
                    if ra.0 < rb.0 {
                        (ra, rb)
                    } else {
                        (rb, ra)
                    }
                }
            };
            self.parent[loser.0 as usize] = winner.0;
        }
    }

    /// Canonical form of a dim: symbolic dims are replaced by their class
    /// representative; if the representative's definition is a constant the
    /// dim collapses to `Fixed`.
    pub fn canon_dim(&self, d: Dim) -> Dim {
        match d {
            Dim::Fixed(n) => Dim::Fixed(n),
            Dim::Sym(s) => {
                let r = self.canon(s);
                if let ShapeExpr::Const(c) = self.def(r) {
                    Dim::Fixed(*c as usize)
                } else {
                    Dim::Sym(r)
                }
            }
        }
    }

    /// True iff the two dims are provably equal under collected constraints.
    pub fn dims_equal(&self, a: Dim, b: Dim) -> bool {
        self.canon_dim(a) == self.canon_dim(b)
    }

    /// True iff the two dim vectors are provably element-wise equal.
    pub fn shapes_equal(&self, a: &[Dim], b: &[Dim]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| self.dims_equal(x, y))
    }

    /// Decompose a dim vector into the element-count monomial
    /// `coeff × Π syms`: the product of every fixed extent, times the
    /// *multiset* of canonical symbolic dims (sorted so equal multisets
    /// compare equal). Two shapes with equal monomials hold the same
    /// element count for every binding; the symbolic memory planner
    /// (`runtime/memplan.rs`) also orders monomials under a bucket lower
    /// bound to prove one buffer always fits inside another.
    pub fn size_monomial(&self, dims: &[Dim]) -> (u64, Vec<SymId>) {
        let mut coeff: u64 = 1;
        let mut syms = Vec::new();
        for &d in dims {
            match self.canon_dim(d) {
                Dim::Fixed(n) => coeff = coeff.saturating_mul(n.max(1) as u64),
                Dim::Sym(s) => syms.push(s),
            }
        }
        syms.sort();
        (coeff, syms)
    }

    // ---- tensor-size equality over IR values ------------------------------

    fn size_canon(&self, v: usize) -> usize {
        let mut cur = v;
        while let Some(&p) = self.size_parent.get(&cur) {
            if p == cur {
                break;
            }
            cur = p;
        }
        cur
    }

    /// Record that IR values `a` and `b` hold tensors with the same number
    /// of elements.
    pub fn record_size_equal(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.size_canon(a), self.size_canon(b));
        if ra != rb {
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.size_parent.insert(hi, lo);
        }
    }

    /// True iff the two values were recorded (transitively) size-equal.
    pub fn size_equal(&self, a: usize, b: usize) -> bool {
        self.size_canon(a) == self.size_canon(b)
    }

    /// Remap IR value ids embedded in symbol definitions and size classes
    /// after a pass rewrites the instruction list. `map[old] = Some(new)`
    /// for surviving values, `None` for removed ones (whose symbols become
    /// unreferenced and are left dangling harmlessly).
    pub fn remap_values(&mut self, map: &[Option<usize>]) {
        fn remap_expr(e: &mut ShapeExpr, map: &[Option<usize>]) {
            match e {
                ShapeExpr::Elem { value, .. } | ShapeExpr::DataDep { value } => {
                    if let Some(Some(nv)) = map.get(*value) {
                        *value = *nv;
                    }
                }
                ShapeExpr::Add(a, b)
                | ShapeExpr::Sub(a, b)
                | ShapeExpr::Mul(a, b)
                | ShapeExpr::CeilDiv(a, b)
                | ShapeExpr::Max(a, b) => {
                    remap_expr(a, map);
                    remap_expr(b, map);
                }
                _ => {}
            }
        }
        for info in &mut self.syms {
            remap_expr(&mut info.def, map);
        }
        let old = std::mem::take(&mut self.size_parent);
        for (k, v) in old {
            if let (Some(Some(nk)), Some(Some(nv))) = (map.get(k), map.get(v)) {
                self.size_parent.insert(*nk, *nv);
            }
        }
    }

    /// Debug dump of all constraint classes (used by `disc inspect`).
    pub fn dump(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut classes: HashMap<SymId, Vec<SymId>> = HashMap::new();
        for i in 0..self.syms.len() {
            let s = SymId(i as u32);
            classes.entry(self.canon(s)).or_default().push(s);
        }
        let mut keys: Vec<_> = classes.keys().copied().collect();
        keys.sort();
        for k in keys {
            let members = &classes[&k];
            let names: Vec<_> = members.iter().map(|s| self.name(*s).to_string()).collect();
            let _ = writeln!(out, "{k} := {} [{}]", self.def(k), names.join(", "));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input_dim(p: usize, a: usize) -> ShapeExpr {
        ShapeExpr::InputDim { param: p, axis: a }
    }

    #[test]
    fn unify_transitive() {
        let mut t = SymbolTable::new();
        let a = t.fresh("a", input_dim(0, 0));
        let b = t.fresh("b", input_dim(1, 0));
        let c = t.fresh("c", input_dim(2, 0));
        assert!(!t.dims_equal(Dim::Sym(a), Dim::Sym(c)));
        t.unify(a, b);
        t.unify(b, c);
        assert!(t.dims_equal(Dim::Sym(a), Dim::Sym(c)));
        assert_eq!(t.canon(c), t.canon(a));
    }

    #[test]
    fn canon_is_smallest_id() {
        let mut t = SymbolTable::new();
        let a = t.fresh("a", input_dim(0, 0));
        let b = t.fresh("b", input_dim(1, 0));
        t.unify(b, a);
        assert_eq!(t.canon(b), a);
    }

    #[test]
    fn const_def_collapses_to_fixed() {
        let mut t = SymbolTable::new();
        let a = t.fresh("a", ShapeExpr::Const(64));
        assert_eq!(t.canon_dim(Dim::Sym(a)), Dim::Fixed(64));
        assert!(t.dims_equal(Dim::Sym(a), Dim::Fixed(64)));
    }

    #[test]
    fn shape_equality_mixed() {
        let mut t = SymbolTable::new();
        let s = t.fresh("seq", input_dim(0, 1));
        let s2 = t.fresh("seq2", input_dim(1, 1));
        let a = [Dim::Fixed(8), Dim::Sym(s), Dim::Fixed(768)];
        let b = [Dim::Fixed(8), Dim::Sym(s2), Dim::Fixed(768)];
        assert!(!t.shapes_equal(&a, &b));
        t.unify(s, s2);
        assert!(t.shapes_equal(&a, &b));
        assert!(!t.shapes_equal(&a[..2], &b));
    }

    #[test]
    fn size_classes() {
        let mut t = SymbolTable::new();
        t.record_size_equal(3, 9);
        t.record_size_equal(9, 12);
        assert!(t.size_equal(3, 12));
        assert!(!t.size_equal(3, 4));
        t.record_size_equal(4, 3);
        assert!(t.size_equal(4, 12));
    }

    #[test]
    fn size_monomial_canonicalizes_and_sorts() {
        let mut t = SymbolTable::new();
        let s = t.fresh("seq", input_dim(0, 1));
        let s2 = t.fresh("seq2", input_dim(1, 1));
        let k = t.fresh("k64", ShapeExpr::Const(64));
        t.unify(s, s2);
        // [s2, 8, s, k64] → coeff 8·64, syms [s, s] (canonical, sorted).
        let (coeff, syms) =
            t.size_monomial(&[Dim::Sym(s2), Dim::Fixed(8), Dim::Sym(s), Dim::Sym(k)]);
        assert_eq!(coeff, 8 * 64);
        assert_eq!(syms, vec![t.canon(s), t.canon(s)]);
        let (c2, sy2) = t.size_monomial(&[Dim::Fixed(2), Dim::Fixed(3)]);
        assert_eq!((c2, sy2.len()), (6, 0));
    }

    #[test]
    fn expr_deps() {
        let mut t = SymbolTable::new();
        let a = t.fresh("a", input_dim(0, 0));
        let e = ShapeExpr::add(ShapeExpr::Dim(Dim::Sym(a)), ShapeExpr::Const(1));
        let mut deps = Vec::new();
        e.deps(&mut deps);
        assert_eq!(deps, vec![a]);
        let e2 = ShapeExpr::mul(
            ShapeExpr::Elem { value: 7, index: 0 },
            ShapeExpr::DataDep { value: 9 },
        );
        let mut vdeps = Vec::new();
        e2.value_deps(&mut vdeps);
        assert_eq!(vdeps, vec![7, 9]);
    }
}
