//! Iteration-level decode scheduling (Orca-style continuous batching).
//!
//! Autoregressive decode inverts the serving problem the parent module
//! solves: a request is not one dispatch but a *loop* of steps, and
//! batching whole requests would hold every member hostage to the longest
//! one. This scheduler batches at **step granularity** instead: all
//! running requests advance one decode step per scheduler iteration, and
//! requests join and leave the running batch only at step boundaries —
//! a finished request's slot frees immediately, a queued request joins
//! mid-flight without waiting for the batch to drain.
//!
//! Each iteration re-groups the running members for dispatch by reusing
//! [`assemble_batch`]'s group-key steering from the parent module: member
//! step inputs are keyed by `group_key_extent` (for decode the residual is
//! empty and the extent is the KV slab's bucket capacity), remembered
//! group shapes steer re-assembly back to recorded batch plans, and ≥2
//! member groups dispatch as one stacked walk (`CompiledModel::run_batch`,
//! bit-identical to solo steps).
//!
//! Request state is **engine-owned**: each member's [`KvCache`] (embedding
//! history + per-layer KV slabs at bucket capacity) lives here, its bytes
//! accounted in the executor arena's KV residency class through the
//! `ArenaLease` returned by `CompiledModel::kv_acquire`. That split is
//! what makes the failure model work — a worker panic mid-step destroys
//! the executor, not the decode state: the member replays the same step
//! (same token, same slab → bit-identical) after the restart, bounded by
//! `max_requeues`. Every exit path (completion, deadline shed, requeue
//! exhaustion, error) drops the member — and with it its slab lease.

use super::{assemble_batch, Request, Stashed};
use crate::compiler::CompiledModel;
use crate::runtime::batching::{group_key_extent, BatchKey};
use crate::runtime::executor::argmax_token;
use crate::runtime::faults::{FaultPlan, FaultSite};
use crate::runtime::kv::{DecodeSpec, KvCache};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::tensor::Tensor;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One decode request: feed the prompt, then generate `gen_steps` tokens.
pub struct DecodeJob {
    pub id: u64,
    pub prompt: Vec<i64>,
    pub gen_steps: usize,
    /// Scheduler iteration at which this job becomes visible to admission
    /// (`0` = at serve start). Deterministic stand-in for arrival time: a
    /// nonzero value exercises the mid-flight join path.
    pub arrive_step: u64,
}

impl DecodeJob {
    pub fn new(id: u64, prompt: Vec<i64>, gen_steps: usize) -> DecodeJob {
        DecodeJob { id, prompt, gen_steps, arrive_step: 0 }
    }
}

/// Decode-serving knobs (the step-loop analogue of `ServeOptions`).
#[derive(Debug, Clone)]
pub struct DecodeServeOptions {
    /// Bound on concurrently running requests (the batch the step loop
    /// re-groups each iteration).
    pub max_batch: usize,
    /// Per-request budget from admission; checked at step boundaries — an
    /// expired member is shed (`deadline_misses`), its slab released.
    pub deadline: Option<Duration>,
    /// Panic-driven step replays a member may absorb before it is shed.
    pub max_requeues: u32,
    /// Fault schedule for worker-panic injection; `None` falls back to the
    /// `DISC_FAULTS` environment spec.
    pub faults: Option<Arc<FaultPlan>>,
    /// Keep every member's per-step probability rows in its completion
    /// (the differential gates compare them bit-for-bit against solo
    /// loops; costs memory proportional to total steps).
    pub capture_probs: bool,
    /// Period of the background re-bucketing loop (see
    /// `ServeOptions::rebucket_interval`); `None` keeps the compile-time
    /// policy. Slab rollovers then target the live boundaries via the
    /// policy switch attached to each member's [`KvCache`].
    pub rebucket_interval: Option<Duration>,
    /// Cut-point budget per symbol for derived boundaries.
    pub max_buckets: usize,
}

impl DecodeServeOptions {
    pub fn batch(max_batch: usize) -> DecodeServeOptions {
        DecodeServeOptions {
            max_batch: max_batch.max(1),
            deadline: None,
            max_requeues: 2,
            faults: None,
            capture_probs: false,
            rebucket_interval: None,
            max_buckets: 8,
        }
    }

    pub fn deadline(mut self, d: Duration) -> DecodeServeOptions {
        self.deadline = Some(d);
        self
    }

    pub fn max_requeues(mut self, n: u32) -> DecodeServeOptions {
        self.max_requeues = n;
        self
    }

    pub fn faults(mut self, plan: Arc<FaultPlan>) -> DecodeServeOptions {
        self.faults = Some(plan);
        self
    }

    pub fn keep_probs(mut self) -> DecodeServeOptions {
        self.capture_probs = true;
        self
    }

    /// Re-derive and hot-swap bucket boundaries every `ms` milliseconds
    /// (`0` turns the loop off).
    pub fn rebucket_every_ms(mut self, ms: u64) -> DecodeServeOptions {
        self.rebucket_interval =
            if ms == 0 { None } else { Some(Duration::from_millis(ms)) };
        self
    }

    /// Cut-point budget per symbol for derived boundaries.
    pub fn max_buckets(mut self, k: usize) -> DecodeServeOptions {
        self.max_buckets = k.max(1);
        self
    }
}

/// One finished decode request.
#[derive(Debug, Clone)]
pub struct DecodeCompletion {
    pub id: u64,
    /// Argmax-sampled token ids, one per generation step.
    pub generated: Vec<i64>,
    /// Total steps executed (prompt + generated).
    pub steps: usize,
    /// Per-step probability rows, kept under `capture_probs` only.
    pub probs: Option<Vec<Tensor>>,
    /// Admission-to-completion latency.
    pub latency: Duration,
}

/// Aggregate decode-serving report.
#[derive(Debug, Clone, Default)]
pub struct DecodeServeReport {
    /// Jobs offered to the scheduler.
    pub offered: usize,
    pub completed: Vec<DecodeCompletion>,
    pub wall: Duration,
    /// Decode steps executed across all members (== tokens processed).
    pub total_steps: u64,
    pub tokens_per_sec: f64,
    /// Step dispatches performed (a stacked group of k members counts 1).
    pub dispatches: u64,
    /// Dispatches that actually ran stacked (≥ 2 members).
    pub batched_dispatches: u64,
    /// Largest running batch observed at any step boundary.
    pub max_occupancy: usize,
    /// Admissions that joined a batch already mid-decode.
    pub joins: u64,
    pub metrics: RunMetrics,
}

/// One running request's engine-owned decode state.
struct Member {
    id: u64,
    kv: KvCache,
    prompt: Vec<i64>,
    gen_steps: usize,
    /// Steps completed so far (== tokens appended to the KV slab).
    step: usize,
    generated: Vec<i64>,
    last_probs: Option<Tensor>,
    probs: Vec<Tensor>,
    admitted: Instant,
    deadline: Option<Instant>,
    requeues: u32,
    /// The member's KV-slab lease in the engine arena; `None` while the
    /// member decodes host-resident (demoted, or a baseline backend with
    /// no arena). Dropping the member releases the slab.
    slab: Option<crate::runtime::buffers::ArenaLease>,
}

impl Member {
    fn total_steps(&self) -> usize {
        self.prompt.len() + self.gen_steps
    }

    /// The token this member feeds at its current step. Pure — a panicked
    /// dispatch replays the step with the identical token.
    fn next_token(&self) -> i64 {
        if self.step < self.prompt.len() {
            self.prompt[self.step]
        } else {
            argmax_token(self.last_probs.as_ref().expect("post-prompt step has probs"))
        }
    }
}

/// Loop-shape counters the report surfaces next to the folded metrics.
#[derive(Default)]
struct LoopStats {
    dispatches: u64,
    batched_dispatches: u64,
    joins: u64,
    max_occupancy: usize,
}

/// Serve a set of decode jobs with iteration-level scheduling: admit at
/// step boundaries up to `max_batch`, advance every running member one
/// step per iteration (re-grouped through `assemble_batch` and dispatched
/// stacked where the step graph batches), retire members as they finish.
/// Upholds the coordinator's zero-lost invariant — every offered job is
/// completed, shed, or deadline-missed — and releases every member's KV
/// slab bytes on every exit path.
pub fn serve_decode(
    model: &mut CompiledModel,
    spec: &DecodeSpec,
    jobs: Vec<DecodeJob>,
    opts: &DecodeServeOptions,
) -> Result<DecodeServeReport> {
    let offered = jobs.len();
    let faults = opts.faults.clone().or_else(FaultPlan::from_env);
    let rebucketer = opts
        .rebucket_interval
        .filter(|iv| !iv.is_zero())
        .and_then(|iv| super::spawn_rebucketer(model, iv, opts.max_buckets));
    let start = Instant::now();
    let mut arrivals: VecDeque<DecodeJob> = jobs.into();
    let mut running: Vec<Member> = Vec::new();
    let mut completions: Vec<DecodeCompletion> = Vec::new();
    let mut metrics = RunMetrics::default();
    let mut stats = LoopStats::default();

    let result = drive(
        model,
        spec,
        opts,
        faults,
        &mut arrivals,
        &mut running,
        &mut completions,
        &mut metrics,
        &mut stats,
    );
    // Error paths leave members behind: their slab leases die with them.
    running.clear();
    if let Some(r) = rebucketer {
        r.stop();
    }
    result?;
    super::fold_policy_metrics(model, &mut metrics);

    let (kv_now, kv_peak) = model.kv_residency();
    anyhow::ensure!(kv_now == 0, "kv slabs leaked: {kv_now} bytes still resident after drain");
    metrics.kv_resident_bytes = metrics.kv_resident_bytes.max(kv_peak);
    metrics.decode_joins = stats.joins;
    let accounted =
        completions.len() as u64 + metrics.shed_requests + metrics.deadline_misses;
    anyhow::ensure!(
        accounted == offered as u64,
        "lost decode jobs: {} completed + {} shed + {} deadline-missed != {offered} offered",
        completions.len(),
        metrics.shed_requests,
        metrics.deadline_misses
    );
    let wall = start.elapsed();
    let total_steps = metrics.decode_steps;
    completions.sort_by_key(|c| c.id);
    Ok(DecodeServeReport {
        offered,
        completed: completions,
        wall,
        total_steps,
        tokens_per_sec: total_steps as f64 / wall.as_secs_f64().max(1e-9),
        dispatches: stats.dispatches,
        batched_dispatches: stats.batched_dispatches,
        max_occupancy: stats.max_occupancy,
        joins: stats.joins,
        metrics,
    })
}

/// The scheduler loop proper; extracted so `serve_decode` can release
/// held slabs on any error path.
#[allow(clippy::too_many_arguments)]
fn drive(
    model: &mut CompiledModel,
    spec: &DecodeSpec,
    opts: &DecodeServeOptions,
    faults: Option<Arc<FaultPlan>>,
    arrivals: &mut VecDeque<DecodeJob>,
    running: &mut Vec<Member>,
    completions: &mut Vec<DecodeCompletion>,
    metrics: &mut RunMetrics,
    stats: &mut LoopStats,
) -> Result<()> {
    let policy = model.bucket_policy();
    let switch = model.policy_switch();
    let ctx = model.batch_context();
    let mut planned_shapes: HashMap<BatchKey, Vec<i64>> = HashMap::new();
    let mut iter = 0u64;

    while !arrivals.is_empty() || !running.is_empty() {
        // -- step-boundary admission (continuous batching's join point) --
        let mid_flight = running.iter().any(|m| m.step > 0);
        let mut i = 0;
        while running.len() < opts.max_batch && i < arrivals.len() {
            if arrivals[i].arrive_step > iter {
                i += 1;
                continue;
            }
            let job = arrivals.remove(i).expect("index checked");
            // Slab rollovers consult the live policy when the backend has
            // a switch: a mid-stream boundary swap redirects the member's
            // next `grow` to the new bucket family.
            let kv = match &switch {
                Some(sw) => KvCache::new(*spec, policy).with_switch(sw.clone()),
                None => KvCache::new(*spec, policy),
            };
            // `Ok(None)` (baseline backend, no arena) is not a demotion —
            // only a failed arena acquire demotes to host residency.
            let slab = match model.kv_acquire(kv.slab_bytes()) {
                Ok(l) => l,
                Err(_) => {
                    metrics.demotions += 1;
                    None
                }
            };
            let now = Instant::now();
            running.push(Member {
                id: job.id,
                kv,
                prompt: job.prompt,
                gen_steps: job.gen_steps,
                step: 0,
                generated: Vec::new(),
                last_probs: None,
                probs: Vec::new(),
                admitted: now,
                deadline: opts.deadline.map(|d| now + d),
                requeues: 0,
                slab,
            });
            metrics.decode_requests += 1;
            if mid_flight {
                stats.joins += 1;
            }
        }
        stats.max_occupancy = stats.max_occupancy.max(running.len());

        // -- step-boundary shedding: expired members never run a step --
        let now = Instant::now();
        let mut j = 0;
        while j < running.len() {
            if running[j].deadline.is_some_and(|d| now >= d) {
                running.remove(j);
                metrics.deadline_misses += 1;
            } else {
                j += 1;
            }
        }
        iter += 1;
        if running.is_empty() {
            continue; // nothing runnable yet (future arrivals only)
        }

        // -- build every member's step inputs (rolling buckets over) --
        let mut tokens: HashMap<u64, i64> = HashMap::new();
        let mut ready: VecDeque<Stashed> = VecDeque::new();
        let mut key_of = |req: &Request| {
            ctx.as_ref().and_then(|(p, a)| group_key_extent(&p.module, a, &req.inputs))
        };
        for m in running.iter_mut() {
            if m.kv.full() {
                // Bucket rollover at the step boundary: the member's next
                // step binds (and on first sight records) the next
                // capacity's plan family.
                m.kv.grow();
                metrics.kv_rollovers += 1;
                if m.slab.is_some() {
                    drop(m.slab.take());
                    m.slab = match model.kv_acquire(m.kv.slab_bytes()) {
                        Ok(l) => l,
                        Err(_) => {
                            metrics.demotions += 1;
                            None
                        }
                    };
                }
            }
            let token = m.next_token();
            tokens.insert(m.id, token);
            let req = Request {
                id: m.id,
                inputs: m.kv.step_inputs(token)?,
                arrived: m.admitted,
                deadline: m.deadline,
                requeues: m.requeues,
            };
            let tag = key_of(&req);
            ready.push_back(Stashed { req, tag });
        }

        // -- per-step re-group: the parent's group-key steering, verbatim
        // semantics (members whose keys agree stack; remembered shapes are
        // preferred so repeat compositions replay recorded batch plans) --
        while let Some(head) = ready.pop_front() {
            let group = head.tag.as_ref().map(|(k, _)| k.clone());
            let target = group.as_ref().and_then(|k| planned_shapes.get(k)).cloned();
            let (batch, shape) = assemble_batch(
                head.req,
                head.tag,
                &mut ready,
                opts.max_batch,
                Duration::ZERO,
                target.as_deref(),
                &mut key_of,
                &mut || None,
            );
            let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
            let inputs: Vec<Vec<Tensor>> = batch.into_iter().map(|r| r.inputs).collect();
            let dispatched = catch_unwind(AssertUnwindSafe(|| {
                if let Some(f) = &faults {
                    if f.should_fail(FaultSite::WorkerPanic) {
                        panic!("injected panic fault (decode step dispatch)");
                    }
                }
                model.run_batch(&inputs)
            }));
            match dispatched {
                Ok(Ok(out)) => {
                    stats.dispatches += 1;
                    *metrics += &out.metrics;
                    if out.metrics.batched_launches > 0 {
                        stats.batched_dispatches += 1;
                        if shape.len() > 1 {
                            if let Some(k) = group {
                                planned_shapes.insert(k, shape);
                            }
                        }
                    }
                    for (id, outs) in ids.into_iter().zip(out.outputs) {
                        advance_member(
                            running,
                            id,
                            tokens[&id],
                            outs,
                            spec,
                            opts,
                            completions,
                            metrics,
                        )?;
                    }
                }
                Ok(Err(e)) => return Err(e),
                Err(_panicked) => {
                    // The step dispatch panicked: restart the engine, keep
                    // the decode state. The fresh executor's arena starts
                    // empty, so every still-resident member re-accounts
                    // its slab; members that burned their requeue budget
                    // are shed, the rest replay this step next iteration.
                    metrics.worker_restarts += 1;
                    model.restart_worker();
                    for m in running.iter_mut() {
                        if m.slab.is_some() {
                            // The old engine's arena died with it; the
                            // stale lease unwinds there, and the member
                            // re-accounts against the fresh arena.
                            drop(m.slab.take());
                            m.slab = match model.kv_acquire(m.kv.slab_bytes()) {
                                Ok(l) => l,
                                Err(_) => {
                                    metrics.demotions += 1;
                                    None
                                }
                            };
                        }
                    }
                    for id in ids {
                        let Some(pos) = running.iter().position(|m| m.id == id) else {
                            continue;
                        };
                        if running[pos].requeues >= opts.max_requeues {
                            running.remove(pos);
                            metrics.shed_requests += 1;
                        } else {
                            running[pos].requeues += 1;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Fold one member's step outputs back into its state: append the KV
/// rows, advance the cursor, retire the member if this was its last step
/// (dropping its slab lease and emitting a completion).
#[allow(clippy::too_many_arguments)]
fn advance_member(
    running: &mut Vec<Member>,
    id: u64,
    token: i64,
    mut outs: Vec<Tensor>,
    spec: &DecodeSpec,
    opts: &DecodeServeOptions,
    completions: &mut Vec<DecodeCompletion>,
    metrics: &mut RunMetrics,
) -> Result<()> {
    let pos = running
        .iter()
        .position(|m| m.id == id)
        .expect("dispatched member is running");
    let m = &mut running[pos];
    anyhow::ensure!(
        outs.len() == 1 + spec.layers,
        "decode step returned {} outputs, want probs + {} kv rows",
        outs.len(),
        spec.layers
    );
    let kv_rows = outs.split_off(1);
    m.kv.append(&kv_rows)?;
    let probs = outs.pop().expect("probs output");
    if m.step >= m.prompt.len() {
        m.generated.push(token);
    }
    m.step += 1;
    metrics.decode_steps += 1;
    if opts.capture_probs {
        m.probs.push(probs.clone());
    }
    m.last_probs = Some(probs);
    if m.step == m.total_steps() {
        let m = running.remove(pos);
        completions.push(DecodeCompletion {
            id: m.id,
            generated: m.generated,
            steps: m.step,
            probs: if opts.capture_probs { Some(m.probs) } else { None },
            latency: m.admitted.elapsed(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};

    fn decode_model() -> CompiledModel {
        let g = crate::workloads::decode::graph();
        let m = crate::bridge::lower(&g).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap()
    }

    #[test]
    fn continuous_batching_matches_solo_decode_loops() {
        let spec = crate::workloads::decode::spec();
        let mut model = decode_model();
        let jobs = vec![
            DecodeJob::new(0, vec![3, 1, 4], 8),
            DecodeJob::new(1, vec![2, 7], 9),
            DecodeJob { id: 2, prompt: vec![5], gen_steps: 7, arrive_step: 3 },
        ];
        let opts = DecodeServeOptions::batch(4).keep_probs();
        let report = serve_decode(&mut model, &spec, jobs, &opts).unwrap();
        assert_eq!(report.completed.len(), 3);
        assert_eq!(report.offered, 3);
        assert!(report.joins >= 1, "job 2 must join the running batch mid-flight");
        assert!(report.batched_dispatches >= 1, "same-capacity steps must stack");
        assert!(report.max_occupancy >= 2);
        assert_eq!(report.total_steps, (3 + 8) + (2 + 9) + (1 + 7));
        assert_eq!(model.kv_residency().0, 0, "all slabs released at drain");

        // The lock: continuous batching is bit-identical to solo
        // step-by-step decode loops, member by member.
        let mut solo = decode_model();
        let cases: [(&[i64], usize); 3] = [(&[3, 1, 4], 8), (&[2, 7], 9), (&[5], 7)];
        for c in &report.completed {
            let (prompt, gen) = cases[c.id as usize];
            let want = solo.run_decode(&spec, prompt, gen).unwrap();
            assert_eq!(c.generated, want.generated, "job {}: token stream", c.id);
            assert_eq!(c.steps, want.steps);
            let probs = c.probs.as_ref().expect("captured");
            assert_eq!(probs.len(), want.step_probs.len());
            for (a, b) in probs.iter().zip(&want.step_probs) {
                assert_eq!(a, b, "job {}: step probs must be bit-exact", c.id);
            }
        }
    }

    #[test]
    fn decode_deadline_sheds_at_step_boundaries() {
        let spec = crate::workloads::decode::spec();
        let mut model = decode_model();
        let jobs = vec![DecodeJob::new(0, vec![1], 4), DecodeJob::new(1, vec![2], 4)];
        let opts = DecodeServeOptions::batch(2).deadline(Duration::ZERO);
        let report = serve_decode(&mut model, &spec, jobs, &opts).unwrap();
        assert_eq!(report.completed.len(), 0);
        assert_eq!(report.metrics.deadline_misses, 2, "both jobs expire at the boundary");
        assert_eq!(model.kv_residency().0, 0, "shed members release their slabs");
    }

    #[test]
    fn decode_panic_restarts_engine_and_replays_members() {
        let spec = crate::workloads::decode::spec();
        let plan = Arc::new(FaultPlan::parse("seed=7,panic=1000:1").unwrap());
        let mut model = decode_model();
        let jobs = vec![DecodeJob::new(0, vec![4, 2], 6), DecodeJob::new(1, vec![9], 5)];
        let opts = DecodeServeOptions::batch(2).max_requeues(2).faults(plan).keep_probs();
        let report = serve_decode(&mut model, &spec, jobs, &opts).unwrap();
        assert_eq!(report.metrics.worker_restarts, 1, "one injected panic, one restart");
        assert_eq!(report.completed.len(), 2, "requeued members finish after the restart");
        assert_eq!(model.kv_residency().0, 0);

        // Engine-owned KV state survives the restart: the replayed step is
        // bit-identical, so the whole stream matches a fault-free run.
        let mut clean = decode_model();
        let cases: [(&[i64], usize); 2] = [(&[4, 2], 6), (&[9], 5)];
        for c in &report.completed {
            let (prompt, gen) = cases[c.id as usize];
            let want = clean.run_decode(&spec, prompt, gen).unwrap();
            assert_eq!(c.generated, want.generated, "job {}: restart must not fork", c.id);
        }
    }

    #[test]
    fn decode_requeue_exhaustion_sheds_and_releases() {
        let spec = crate::workloads::decode::spec();
        let plan = Arc::new(FaultPlan::parse("seed=8,panic=1000:1").unwrap());
        let mut model = decode_model();
        let jobs = vec![DecodeJob::new(0, vec![1], 3), DecodeJob::new(1, vec![2], 3)];
        let opts = DecodeServeOptions::batch(2).max_requeues(0).faults(plan);
        let report = serve_decode(&mut model, &spec, jobs, &opts).unwrap();
        assert_eq!(report.completed.len(), 0, "zero requeue budget sheds on first panic");
        assert_eq!(report.metrics.shed_requests, 2);
        assert_eq!(report.metrics.worker_restarts, 1);
        assert_eq!(model.kv_residency().0, 0, "shed members release their slabs");
    }
}
