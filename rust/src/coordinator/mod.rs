//! Serving coordinator: the request loop wrapped around compiled models.
//!
//! DISC's artifact is a compiler, but it is deployed inside serving
//! systems; this coordinator is the harness the end-to-end example and the
//! benches drive. It owns a request queue fed by a generator thread,
//! executes requests against a `CompiledModel` (single executor loop — the
//! PJRT client and kernel caches are deliberately not shared across
//! threads, as in the paper's per-stream deployment), and reports latency
//! percentiles, throughput, and the accumulated metric counters.
//!
//! Two drive modes: `serve_closed_loop` (next request issues when the
//! previous completes — the benches' steady-state measurement) and
//! `serve_open_loop` (requests arrive at a fixed rate regardless of
//! completion, exposing queueing under load). Both aggregate `RunMetrics`
//! with its `+=` semantics, so plan-cache, weight-cache, and transfer
//! counters read as stream totals. See `docs/architecture.md` for where
//! the coordinator sits in the pipeline and `docs/runtime.md` for the
//! executor tiers underneath it.

use crate::compiler::CompiledModel;
use crate::runtime::metrics::RunMetrics;
use crate::runtime::tensor::Tensor;
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub inputs: Vec<Tensor>,
    pub arrived: Instant,
}

/// Per-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub latency: Duration,
    pub queue_delay: Duration,
}

/// Aggregate serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub completed: usize,
    pub wall: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub mean: Duration,
    pub throughput_rps: f64,
    pub metrics: RunMetrics,
}

impl ServeReport {
    fn from_completions(
        mut lat: Vec<Completion>,
        wall: Duration,
        metrics: RunMetrics,
    ) -> ServeReport {
        if lat.is_empty() {
            return ServeReport { wall, metrics, ..Default::default() };
        }
        lat.sort_by_key(|c| c.latency);
        let pick = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize].latency;
        let mean = lat.iter().map(|c| c.latency).sum::<Duration>() / lat.len() as u32;
        ServeReport {
            completed: lat.len(),
            wall,
            p50: pick(0.50),
            p95: pick(0.95),
            p99: pick(0.99),
            mean,
            throughput_rps: lat.len() as f64 / wall.as_secs_f64().max(1e-9),
            metrics,
        }
    }
}

/// Drive a compiled model over a pre-generated request stream, closed-loop
/// (back-to-back, as the paper's inference measurements are).
pub fn serve_closed_loop(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
) -> Result<ServeReport> {
    let start = Instant::now();
    let mut completions = Vec::with_capacity(stream.len());
    let mut metrics = RunMetrics::default();
    for (i, inputs) in stream.into_iter().enumerate() {
        let t0 = Instant::now();
        let out = model.run(&inputs)?;
        metrics += &out.metrics;
        completions.push(Completion {
            id: i as u64,
            latency: t0.elapsed(),
            queue_delay: Duration::ZERO,
        });
    }
    Ok(ServeReport::from_completions(completions, start.elapsed(), metrics))
}

/// Open-loop serving: a producer thread feeds the queue at a fixed rate
/// while this thread (owning the model — PJRT state is not `Send`) drains
/// it. Queue delay shows up in latency, as in a real deployment.
pub fn serve_open_loop(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
    rate_rps: f64,
) -> Result<ServeReport> {
    let (tx, rx) = mpsc::channel::<Request>();
    let n = stream.len();
    let producer = std::thread::spawn(move || {
        let gap = Duration::from_secs_f64(1.0 / rate_rps.max(1e-3));
        for (i, inputs) in stream.into_iter().enumerate() {
            let _ = tx.send(Request { id: i as u64, inputs, arrived: Instant::now() });
            std::thread::sleep(gap);
        }
    });

    let start = Instant::now();
    let mut completions = Vec::with_capacity(n);
    let mut metrics = RunMetrics::default();
    while completions.len() < n {
        let req = rx.recv()?;
        let queue_delay = req.arrived.elapsed();
        let t0 = Instant::now();
        let out = model.run(&req.inputs)?;
        metrics += &out.metrics;
        completions.push(Completion {
            id: req.id,
            latency: queue_delay + t0.elapsed(),
            queue_delay,
        });
    }
    producer.join().ok();
    Ok(ServeReport::from_completions(completions, start.elapsed(), metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};

    fn small_model() -> CompiledModel {
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap()
    }

    #[test]
    fn closed_loop_serves_stream() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(8, 42);
        let report = serve_closed_loop(&mut model, stream).unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95 >= report.p50);
        assert!(report.metrics.mem_kernels > 0);
    }

    #[test]
    fn open_loop_includes_queue_delay() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(5, 43);
        let report = serve_open_loop(&mut model, stream, 200.0).unwrap();
        assert_eq!(report.completed, 5);
        assert!(report.mean > Duration::ZERO);
    }
}
