//! Serving coordinator: the multi-worker request loop wrapped around
//! compiled models.
//!
//! DISC's artifact is a compiler, but it is deployed inside serving
//! systems; this coordinator is the harness the end-to-end example and the
//! benches drive. Since the multi-worker refactor it scales past the
//! paper's per-stream deployment: [`serve_open_loop`] runs `workers`
//! executor threads draining **one bounded queue**, every worker sharing
//! the process-wide kernel store, weight store, and background compile
//! pool (each pattern×bucket compiles once, each weight uploads once —
//! whichever worker gets there first) while keeping its own launch-plan
//! cache and buffer arena. See docs/runtime.md §Concurrency model for the
//! per-worker vs process-shared split.
//!
//! Drive modes:
//!
//! * [`serve_closed_loop`] — next request issues when the previous
//!   completes (the benches' steady-state measurement, single worker).
//! * [`serve_open_loop`] — requests arrive on a producer thread at a fixed
//!   offered rate regardless of completion, exposing queueing under load.
//!   The producer schedules against **absolute deadlines** (`next += gap`),
//!   so send overhead never drifts the offered rate, and supports an
//!   on/off **bursty** arrival mode ([`Arrival::Bursty`]) for the
//!   multi-tenant study.
//!
//! Reports aggregate `RunMetrics` with its `+=` semantics (stream totals),
//! carry nearest-rank latency and queue-delay percentiles, and — under
//! multiple workers — a per-worker breakdown.

use crate::compiler::CompiledModel;
use crate::runtime::metrics::RunMetrics;
use crate::runtime::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub inputs: Vec<Tensor>,
    pub arrived: Instant,
}

/// Per-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub latency: Duration,
    pub queue_delay: Duration,
}

/// Arrival process of the open-loop producer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Evenly spaced arrivals at the offered rate.
    Uniform,
    /// On/off bursts: `burst` requests sent back-to-back, then an idle gap
    /// sized so the *average* offered rate still matches `rate_rps`. This
    /// is the bursty multi-tenant shape the ROADMAP's open item asks for:
    /// queue delay concentrates at burst heads and melts with workers.
    Bursty { burst: usize },
}

/// Open-loop serving knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Offered request rate (requests/second, averaged over the stream).
    pub rate_rps: f64,
    /// Executor worker threads draining the queue. `1` keeps everything on
    /// the calling thread (any backend); `>1` forks sibling executors from
    /// the model (program backends only).
    pub workers: usize,
    pub arrival: Arrival,
    /// Bound of the request queue; the producer blocks when it is full
    /// (backpressure instead of unbounded memory under overload).
    pub queue_cap: usize,
}

impl ServeOptions {
    /// Uniform single-worker open loop at `rate_rps` (the pre-multi-worker
    /// behavior).
    pub fn rate(rate_rps: f64) -> ServeOptions {
        ServeOptions { rate_rps, workers: 1, arrival: Arrival::Uniform, queue_cap: 1024 }
    }

    pub fn workers(mut self, n: usize) -> ServeOptions {
        self.workers = n.max(1);
        self
    }

    pub fn bursty(mut self, burst: usize) -> ServeOptions {
        self.arrival = Arrival::Bursty { burst: burst.max(1) };
        self
    }
}

/// One worker's slice of an open-loop run.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    pub worker: usize,
    pub completed: usize,
    pub mean: Duration,
    pub p99: Duration,
    pub metrics: RunMetrics,
}

impl WorkerReport {
    /// Summarize one worker's completions (single source for the mean /
    /// nearest-rank math, used by both serve paths).
    fn summarize(worker: usize, completions: &[Completion], metrics: RunMetrics) -> WorkerReport {
        let mut lats: Vec<Duration> = completions.iter().map(|c| c.latency).collect();
        lats.sort_unstable();
        let mean = if lats.is_empty() {
            Duration::ZERO
        } else {
            lats.iter().sum::<Duration>() / lats.len() as u32
        };
        WorkerReport {
            worker,
            completed: completions.len(),
            mean,
            p99: nearest_rank(&lats, 0.99),
            metrics,
        }
    }
}

/// Aggregate serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub completed: usize,
    pub wall: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub mean: Duration,
    /// Nearest-rank percentiles of queue delay (time between arrival and a
    /// worker picking the request up) — the congestion signal the worker
    /// sweep is about.
    pub queue_p50: Duration,
    pub queue_p99: Duration,
    pub throughput_rps: f64,
    pub metrics: RunMetrics,
    /// Per-worker breakdown (one entry per worker on multi-worker runs;
    /// single entry otherwise).
    pub per_worker: Vec<WorkerReport>,
}

/// Nearest-rank percentile over an ascending-sorted sample: the smallest
/// value with at least `q·n` samples at or below it (`sorted[⌈q·n⌉ − 1]`).
/// The previous `((n−1)·q) as usize` pick *floored*, which collapsed p99
/// onto p95 for small streams and systematically understated tails.
fn nearest_rank(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

impl ServeReport {
    fn from_completions(
        lat: Vec<Completion>,
        wall: Duration,
        metrics: RunMetrics,
        per_worker: Vec<WorkerReport>,
    ) -> ServeReport {
        if lat.is_empty() {
            return ServeReport { wall, metrics, per_worker, ..Default::default() };
        }
        let mut latencies: Vec<Duration> = lat.iter().map(|c| c.latency).collect();
        latencies.sort_unstable();
        let mut queue: Vec<Duration> = lat.iter().map(|c| c.queue_delay).collect();
        queue.sort_unstable();
        let mean = latencies.iter().sum::<Duration>() / latencies.len() as u32;
        ServeReport {
            completed: lat.len(),
            wall,
            p50: nearest_rank(&latencies, 0.50),
            p95: nearest_rank(&latencies, 0.95),
            p99: nearest_rank(&latencies, 0.99),
            mean,
            queue_p50: nearest_rank(&queue, 0.50),
            queue_p99: nearest_rank(&queue, 0.99),
            throughput_rps: lat.len() as f64 / wall.as_secs_f64().max(1e-9),
            metrics,
            per_worker,
        }
    }
}

/// Drive a compiled model over a pre-generated request stream, closed-loop
/// (back-to-back, as the paper's inference measurements are).
pub fn serve_closed_loop(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
) -> Result<ServeReport> {
    let start = Instant::now();
    let mut completions = Vec::with_capacity(stream.len());
    let mut metrics = RunMetrics::default();
    for (i, inputs) in stream.into_iter().enumerate() {
        let t0 = Instant::now();
        let out = model.run(&inputs)?;
        metrics += &out.metrics;
        completions.push(Completion {
            id: i as u64,
            latency: t0.elapsed(),
            queue_delay: Duration::ZERO,
        });
    }
    Ok(ServeReport::from_completions(completions, start.elapsed(), metrics, Vec::new()))
}

/// Spawn the open-loop producer: absolute-deadline scheduling (the gap is
/// added to the *previous deadline*, never to "now", so per-send overhead
/// cannot accumulate into the offered rate) with optional on/off bursts.
fn spawn_producer(
    tx: mpsc::SyncSender<Request>,
    stream: Vec<Vec<Tensor>>,
    rate_rps: f64,
    arrival: Arrival,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let gap = Duration::from_secs_f64(1.0 / rate_rps.max(1e-3));
        let burst = match arrival {
            Arrival::Uniform => 1,
            Arrival::Bursty { burst } => burst.max(1),
        };
        let mut next_deadline = Instant::now();
        for (i, inputs) in stream.into_iter().enumerate() {
            // Burst heads wait for their deadline; the rest of the burst
            // goes back-to-back. Advancing the deadline by `gap` per
            // request keeps the average offered rate exact in both modes.
            if i % burst == 0 {
                let now = Instant::now();
                if next_deadline > now {
                    std::thread::sleep(next_deadline - now);
                }
            }
            next_deadline += gap;
            if tx.send(Request { id: i as u64, inputs, arrived: Instant::now() }).is_err() {
                return; // consumers died (error path): stop offering
            }
        }
    })
}

/// Open-loop serving: a producer thread feeds one bounded queue at the
/// offered rate while `opts.workers` executor threads drain it. Queue
/// delay shows up in latency, as in a real deployment.
///
/// With `workers == 1` the calling thread drains the queue against the
/// model directly (any backend). With more, sibling executors are forked
/// from the model (see [`CompiledModel::fork_workers`]): per-worker plan
/// caches, shared kernel/weight stores — the compile-once, upload-once
/// serving engine.
pub fn serve_open_loop(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let n = stream.len();
    if opts.workers <= 1 {
        let (tx, rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
        let producer = spawn_producer(tx, stream, opts.rate_rps, opts.arrival);
        let start = Instant::now();
        let mut completions = Vec::with_capacity(n);
        let mut metrics = RunMetrics::default();
        while completions.len() < n {
            let req = rx.recv().context("open-loop producer hung up early")?;
            let queue_delay = req.arrived.elapsed();
            let t0 = Instant::now();
            let out = model.run(&req.inputs)?;
            metrics += &out.metrics;
            completions.push(Completion {
                id: req.id,
                latency: queue_delay + t0.elapsed(),
                queue_delay,
            });
        }
        producer.join().ok();
        let wall = start.elapsed();
        let per_worker = vec![WorkerReport::summarize(0, &completions, metrics.clone())];
        return Ok(ServeReport::from_completions(completions, wall, metrics, per_worker));
    }

    // Multi-worker: fork sibling executors and drain the shared queue.
    let (prog, workers) = model.fork_workers(opts.workers)?;
    let (tx, rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
    let rx = Arc::new(Mutex::new(rx));
    let producer = spawn_producer(tx, stream, opts.rate_rps, opts.arrival);
    let start = Instant::now();

    let handles: Vec<_> = workers
        .into_iter()
        .enumerate()
        .map(|(wi, mut exec)| {
            let rx = rx.clone();
            let prog = prog.clone();
            std::thread::Builder::new()
                .name(format!("disc-worker-{wi}"))
                .spawn(move || -> Result<(usize, Vec<Completion>, RunMetrics)> {
                    let mut completions = Vec::new();
                    let mut metrics = RunMetrics::default();
                    loop {
                        // Hold the receiver lock only for the dequeue; the
                        // (long) model run happens outside it.
                        let req = {
                            let guard = rx.lock().expect("request queue lock");
                            guard.recv()
                        };
                        let Ok(req) = req else { break };
                        let queue_delay = req.arrived.elapsed();
                        let t0 = Instant::now();
                        let out = exec
                            .run(&prog, &req.inputs)
                            .with_context(|| format!("worker {wi}, request {}", req.id))?;
                        metrics += &out.metrics;
                        completions.push(Completion {
                            id: req.id,
                            latency: queue_delay + t0.elapsed(),
                            queue_delay,
                        });
                    }
                    Ok((wi, completions, metrics))
                })
                .expect("spawning worker thread")
        })
        .collect();

    let mut completions: Vec<Completion> = Vec::with_capacity(n);
    let mut metrics = RunMetrics::default();
    let mut per_worker: Vec<WorkerReport> = Vec::with_capacity(handles.len());
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join().expect("worker thread panicked") {
            Ok((wi, comps, m)) => {
                per_worker.push(WorkerReport::summarize(wi, &comps, m.clone()));
                metrics += &m;
                completions.extend(comps);
            }
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    // Workers have exited (normally when the producer closed the queue, or
    // on error). Dropping our receiver handle disconnects a producer that
    // is still blocked on a full queue after an all-workers failure, so the
    // join below cannot deadlock.
    drop(rx);
    producer.join().ok();
    if let Some(e) = first_err {
        return Err(e);
    }
    anyhow::ensure!(completions.len() == n, "lost requests: {} of {n} completed", completions.len());
    let wall = start.elapsed();
    per_worker.sort_by_key(|w| w.worker);
    Ok(ServeReport::from_completions(completions, wall, metrics, per_worker))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};

    fn small_model() -> CompiledModel {
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap()
    }

    #[test]
    fn closed_loop_serves_stream() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(8, 42);
        let report = serve_closed_loop(&mut model, stream).unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95 >= report.p50);
        assert!(report.metrics.mem_kernels > 0);
    }

    #[test]
    fn open_loop_includes_queue_delay() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(5, 43);
        let report = serve_open_loop(&mut model, stream, &ServeOptions::rate(200.0)).unwrap();
        assert_eq!(report.completed, 5);
        assert!(report.mean > Duration::ZERO);
        assert_eq!(report.per_worker.len(), 1);
    }

    #[test]
    fn multi_worker_open_loop_completes_and_aggregates() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(12, 44);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(5_000.0).workers(3),
        )
        .unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.per_worker.len(), 3);
        assert_eq!(report.per_worker.iter().map(|wr| wr.completed).sum::<usize>(), 12);
        assert!(report.metrics.mem_kernels > 0, "metrics aggregate across workers");
    }

    #[test]
    fn multi_worker_requires_program_backend() {
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Eager)).unwrap();
        let err = serve_open_loop(
            &mut model,
            w.request_stream(2, 45),
            &ServeOptions::rate(100.0).workers(2),
        );
        assert!(err.is_err(), "eager backend cannot fork workers");
    }

    #[test]
    fn bursty_arrival_completes_the_stream() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(9, 46);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(3_000.0).workers(2).bursty(4),
        )
        .unwrap();
        assert_eq!(report.completed, 9);
        assert!(report.queue_p99 >= report.queue_p50);
    }

    #[test]
    fn nearest_rank_percentiles_do_not_collapse_tails() {
        // 100 distinct latencies 1..=100 ms.
        let mk = |ms: u64| Duration::from_millis(ms);
        let sorted: Vec<Duration> = (1..=100).map(mk).collect();
        assert_eq!(nearest_rank(&sorted, 0.50), mk(50));
        assert_eq!(nearest_rank(&sorted, 0.95), mk(95));
        assert_eq!(nearest_rank(&sorted, 0.99), mk(99));
        assert_eq!(nearest_rank(&sorted, 1.0), mk(100));
        // Small stream: p99 is the max (the floored pick used to report
        // the 9th of 10 samples for BOTH p95 and p99, understating the
        // tail; the old formula gave index 8 = 9ms here).
        let small: Vec<Duration> = (1..=10).map(mk).collect();
        assert_eq!(nearest_rank(&small, 0.99), mk(10));
        assert_eq!(nearest_rank(&small, 0.50), mk(5));
        // Degenerate cases.
        assert_eq!(nearest_rank(&[], 0.99), Duration::ZERO);
        assert_eq!(nearest_rank(&[mk(7)], 0.01), mk(7));
    }

    #[test]
    fn producer_deadline_scheduling_holds_offered_rate() {
        // 30 requests at 1 kHz must take ~30ms of producer time, not
        // 30×(gap + per-send overhead). Generous upper bound for CI noise;
        // the old sleep-after-send producer also always passed the lower
        // bound, so the assertion that catches the drift bug is the upper.
        let (tx, rx) = mpsc::sync_channel::<Request>(64);
        let stream: Vec<Vec<Tensor>> = (0..30).map(|_| Vec::new()).collect();
        let t0 = Instant::now();
        let h = spawn_producer(tx, stream, 1_000.0, Arrival::Uniform);
        let mut got = 0;
        while rx.recv().is_ok() {
            got += 1;
        }
        h.join().unwrap();
        let took = t0.elapsed();
        assert_eq!(got, 30);
        assert!(took >= Duration::from_millis(25), "offered faster than the rate: {took:?}");
        assert!(took <= Duration::from_millis(250), "producer drifted: {took:?}");
    }
}
