//! Serving coordinator: the multi-worker request loop wrapped around
//! compiled models.
//!
//! DISC's artifact is a compiler, but it is deployed inside serving
//! systems; this coordinator is the harness the end-to-end example and the
//! benches drive. Since the multi-worker refactor it scales past the
//! paper's per-stream deployment: [`serve_open_loop`] runs `workers`
//! executor threads draining **one bounded queue**, every worker sharing
//! the process-wide kernel store, weight store, and background compile
//! pool (each pattern×bucket compiles once, each weight uploads once —
//! whichever worker gets there first) while keeping its own launch-plan
//! cache and buffer arena. See docs/runtime.md §Concurrency model for the
//! per-worker vs process-shared split.
//!
//! **Cross-request batching** (`ServeOptions::max_batch > 1`): instead of
//! launching every dequeued request alone, a worker greedily drains the
//! queue, groups pending requests whose residual symbol bindings agree
//! (see `runtime::batching`), and dispatches the whole group as one
//! stacked walk of the generated flow — one kernel launch per leading-
//! parallel step for the entire group, bit-identical outputs per member.
//! Assembly is bounded by `max_batch` and by `batch_window` (how long a
//! worker may wait for stragglers once the queue runs dry); singletons,
//! ineligible programs, and binding mismatches fall back to solo
//! execution. Assembly is also **group-key-aware**: each worker
//! remembers the extent multiset of every group it dispatched batched —
//! exactly the shapes the executor recorded batch plans for — and steers
//! later assemblies back to those shapes, so bursty repeat traffic
//! replays recorded batch plans instead of accreting never-seen group
//! shapes (see `runtime::batching` for the batch plan tiers). Reports
//! carry `batch_launches` (total dispatches), `batch_occupancy`
//! (requests per dispatch), and the batching counters inside
//! `RunMetrics`.
//!
//! Drive modes:
//!
//! * [`serve_closed_loop`] — next request issues when the previous
//!   completes (the benches' steady-state measurement, single worker).
//! * [`serve_open_loop`] — requests arrive on a producer thread at a fixed
//!   offered rate regardless of completion, exposing queueing under load.
//!   The producer schedules against **absolute deadlines** (`next += gap`),
//!   so send overhead never drifts the offered rate, and supports an
//!   on/off **bursty** arrival mode ([`Arrival::Bursty`]) for the
//!   multi-tenant study.
//!
//! Reports aggregate `RunMetrics` with its `+=` semantics (stream totals),
//! carry nearest-rank latency and queue-delay percentiles, and — under
//! multiple workers — a per-worker breakdown. `ServeOptions::keep_outputs`
//! additionally captures every request's outputs (by request id), which
//! the batching correctness gates compare bit-for-bit against unbatched
//! runs.
//!
//! **Failure model** (see docs/runtime.md §Failure model): every request
//! offered to [`serve_open_loop`] is accounted exactly once — completed,
//! shed (`RunMetrics::shed_requests`: queue full at admission, or requeue
//! budget exhausted), or deadline-missed (`RunMetrics::deadline_misses`) —
//! and the coordinator `ensure!`s the balance. Worker dispatches run under
//! `catch_unwind` supervision: a panic mid-dispatch requeues the in-flight
//! batch (bounded by `ServeOptions::max_requeues`), swaps in a freshly
//! forked executor, and counts `RunMetrics::worker_restarts`. Panic
//! injection for the chaos gates is armed via `ServeOptions::faults` or
//! the `DISC_FAULTS` environment spec (`runtime::faults`).

pub mod decode;
pub mod tenants;

use crate::compiler::CompiledModel;
use crate::program::Program;
use crate::runtime::batching::{group_key_extent, BatchAnalysis, BatchKey, BatchOutput};
use crate::runtime::faults::{FaultPlan, FaultSite};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub inputs: Vec<Tensor>,
    pub arrived: Instant,
    /// Absolute shed deadline (`arrived + ServeOptions::deadline`); `None`
    /// never expires.
    pub deadline: Option<Instant>,
    /// Times this request was requeued after a worker panic interrupted
    /// its dispatch (bounded by `ServeOptions::max_requeues`).
    pub requeues: u32,
}

/// What a supervised dispatch produced: the inner `Result` is the
/// executor's, the outer layer is `catch_unwind` (a panic mid-dispatch).
type DispatchResult = std::thread::Result<Result<BatchOutput>>;

/// Per-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub latency: Duration,
    pub queue_delay: Duration,
    /// The request's outputs, kept only under
    /// `ServeOptions::capture_outputs` (correctness gates).
    pub outputs: Option<Vec<Tensor>>,
}

/// Arrival process of the open-loop producer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Evenly spaced arrivals at the offered rate.
    Uniform,
    /// On/off bursts: `burst` requests sent back-to-back, then an idle gap
    /// sized so the *average* offered rate still matches `rate_rps`. This
    /// is the bursty multi-tenant shape the ROADMAP's open item asks for:
    /// queue delay concentrates at burst heads and melts with workers.
    Bursty { burst: usize },
}

/// Open-loop serving knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Offered request rate (requests/second, averaged over the stream).
    pub rate_rps: f64,
    /// Executor worker threads draining the queue. `1` keeps everything on
    /// the calling thread (any backend); `>1` forks sibling executors from
    /// the model (program backends only).
    pub workers: usize,
    pub arrival: Arrival,
    /// Bound of the request queue. The producer never blocks on a full
    /// queue (blocking would silently stretch the offered arrival
    /// process); it sheds the request instead, counted in
    /// `RunMetrics::shed_requests`.
    pub queue_cap: usize,
    /// Cross-request batching bound: a worker coalesces up to this many
    /// same-group queued requests into one stacked dispatch. `1` disables
    /// batching (every request launches alone).
    pub max_batch: usize,
    /// How long a worker may wait for stragglers once the queue runs dry
    /// while assembling a batch. Zero means greedy drain only: batch what
    /// is already queued, never trade latency for occupancy.
    pub batch_window: Duration,
    /// Keep every request's outputs in the report (bit-exactness gates;
    /// costs memory proportional to the stream).
    pub capture_outputs: bool,
    /// Per-request latency budget measured from arrival. A request still
    /// undispatched past its deadline is shed at admission control
    /// (`RunMetrics::deadline_misses`) instead of served uselessly late.
    /// `None` (the default) never sheds on age.
    pub deadline: Option<Duration>,
    /// How many times a request whose dispatch was interrupted by a worker
    /// panic may be requeued before it is shed
    /// (`RunMetrics::shed_requests`).
    pub max_requeues: u32,
    /// Fault schedule consulted for worker-panic injection (chaos gates).
    /// `None` falls back to the `DISC_FAULTS` environment spec. Device
    /// seams (compile / transfer / device OOM) are armed on the device
    /// itself — see `runtime::faults`.
    pub faults: Option<Arc<FaultPlan>>,
    /// Period of the background re-bucketing loop: every interval, a
    /// dedicated forked worker re-derives bucket boundaries from the
    /// traffic histogram, pre-compiles the new bucket family off the hot
    /// path, and hot-swaps the policy epoch (see `Executor::rebucket`).
    /// `None` (the default) keeps the compile-time policy for the whole
    /// run. Program backends only; baselines ignore it.
    pub rebucket_interval: Option<Duration>,
    /// Cut-point budget per symbol for derived boundaries (≤K cuts chosen
    /// to minimize expected padded elements).
    pub max_buckets: usize,
}

impl ServeOptions {
    /// Uniform single-worker open loop at `rate_rps`, batching off (the
    /// pre-multi-worker behavior).
    pub fn rate(rate_rps: f64) -> ServeOptions {
        ServeOptions {
            rate_rps,
            workers: 1,
            arrival: Arrival::Uniform,
            queue_cap: 1024,
            max_batch: 1,
            batch_window: Duration::ZERO,
            capture_outputs: false,
            deadline: None,
            max_requeues: 2,
            faults: None,
            rebucket_interval: None,
            max_buckets: 8,
        }
    }

    pub fn workers(mut self, n: usize) -> ServeOptions {
        self.workers = n.max(1);
        self
    }

    pub fn bursty(mut self, burst: usize) -> ServeOptions {
        self.arrival = Arrival::Bursty { burst: burst.max(1) };
        self
    }

    /// Enable cross-request batching up to `max_batch` requests per
    /// dispatch.
    pub fn batch(mut self, max_batch: usize) -> ServeOptions {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Allow workers to wait up to `us` microseconds for batch stragglers
    /// after the queue runs dry.
    pub fn batch_window_us(mut self, us: u64) -> ServeOptions {
        self.batch_window = Duration::from_micros(us);
        self
    }

    /// Capture per-request outputs into the report.
    pub fn keep_outputs(mut self) -> ServeOptions {
        self.capture_outputs = true;
        self
    }

    /// Shed requests still undispatched `ms` milliseconds after arrival.
    pub fn deadline_ms(mut self, ms: u64) -> ServeOptions {
        self.deadline = Some(Duration::from_millis(ms));
        self
    }

    /// Bound panic-driven requeues per request (`0` sheds on the first
    /// panic that interrupts the request).
    pub fn max_requeues(mut self, n: u32) -> ServeOptions {
        self.max_requeues = n;
        self
    }

    /// Arm an explicit fault schedule for worker-panic injection.
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> ServeOptions {
        self.faults = Some(plan);
        self
    }

    /// Re-derive and hot-swap bucket boundaries every `ms` milliseconds
    /// (`0` turns the loop off).
    pub fn rebucket_every_ms(mut self, ms: u64) -> ServeOptions {
        self.rebucket_interval =
            if ms == 0 { None } else { Some(Duration::from_millis(ms)) };
        self
    }

    /// Cut-point budget per symbol for derived boundaries.
    pub fn max_buckets(mut self, k: usize) -> ServeOptions {
        self.max_buckets = k.max(1);
        self
    }
}

/// Handle to the background re-bucketing thread: signal + join on stop.
pub(crate) struct Rebucketer {
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

impl Rebucketer {
    pub(crate) fn stop(self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = self.handle.join();
    }
}

/// Spawn the coordinator's background re-bucketing loop: a dedicated
/// worker forked from the model (sharing its policy switch, histogram,
/// kernel store, and compile pool) wakes every `interval`, re-derives
/// boundaries from the traffic observed so far, pre-compiles the new
/// bucket family through the background compile pool, and flips the
/// epoch — all off the serving hot path (see `Executor::rebucket`).
/// Returns `None` for baseline backends (no forked workers, no switch).
pub(crate) fn spawn_rebucketer(
    model: &CompiledModel,
    interval: Duration,
    max_cuts: usize,
) -> Option<Rebucketer> {
    use std::sync::atomic::{AtomicBool, Ordering};
    let (prog, mut workers) = model.fork_workers(1).ok()?;
    let mut exec = workers.pop()?;
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let handle = std::thread::Builder::new()
        .name("disc-rebucket".into())
        .spawn(move || loop {
            // Stop-checked sleep in short slices so shutdown never waits
            // out a whole interval.
            let mut slept = Duration::ZERO;
            while slept < interval {
                if flag.load(Ordering::Relaxed) {
                    return;
                }
                let slice = (interval - slept).min(Duration::from_millis(5));
                std::thread::sleep(slice);
                slept += slice;
            }
            if flag.load(Ordering::Relaxed) {
                return;
            }
            // A failed cycle (e.g. an injected compile fault during
            // pre-warm) leaves the live policy untouched; the next tick
            // retries with more traffic observed.
            let _ = exec.rebucket(&prog, max_cuts);
        })
        .expect("spawning rebucket thread");
    Some(Rebucketer { stop, handle })
}

/// Fold the live policy switch's observability gauges into a finished
/// report: swap count, final epoch, and a snapshot of the per-symbol
/// extent histogram (the satellite counters next to `padding_ratio`).
pub(crate) fn fold_policy_metrics(model: &CompiledModel, metrics: &mut RunMetrics) {
    if let Some(sw) = model.policy_switch() {
        metrics.rebucket_swaps = metrics.rebucket_swaps.max(sw.swaps());
        metrics.policy_epoch = metrics.policy_epoch.max(sw.epoch());
        let snap = sw.histogram.snapshot();
        metrics.extent_hist =
            snap.per_sym.into_iter().map(|(s, bins)| (s.0, bins)).collect();
    }
}

/// One worker's slice of an open-loop run.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    pub worker: usize,
    /// Requests this worker served (batch members count individually).
    pub completed: usize,
    /// Dispatches this worker performed: a batch of k counts once, a solo
    /// run counts once. Diverges from `completed` exactly when batching
    /// coalesces requests.
    pub launches: usize,
    pub mean: Duration,
    pub p99: Duration,
    pub metrics: RunMetrics,
}

impl WorkerReport {
    /// Summarize one worker's completions (single source for the mean /
    /// nearest-rank math, used by both serve paths).
    fn summarize(
        worker: usize,
        completions: &[Completion],
        launches: usize,
        metrics: RunMetrics,
    ) -> WorkerReport {
        let mut lats: Vec<Duration> = completions.iter().map(|c| c.latency).collect();
        lats.sort_unstable();
        let mean = if lats.is_empty() {
            Duration::ZERO
        } else {
            lats.iter().sum::<Duration>() / lats.len() as u32
        };
        WorkerReport {
            worker,
            completed: completions.len(),
            launches,
            mean,
            p99: nearest_rank(&lats, 0.99),
            metrics,
        }
    }
}

/// Aggregate serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub completed: usize,
    pub wall: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub mean: Duration,
    /// Nearest-rank percentiles of queue delay (time between arrival and a
    /// worker picking the request up) — the congestion signal the worker
    /// sweep is about.
    pub queue_p50: Duration,
    pub queue_p99: Duration,
    pub throughput_rps: f64,
    /// Total dispatches across all workers (a batch of k counts once).
    /// With batching off this equals `completed`; with batching on it is
    /// strictly smaller whenever any batch formed.
    pub batch_launches: usize,
    /// Requests that rode a batched (>= 2 member) dispatch, from
    /// `RunMetrics::batched_requests`.
    pub batched_requests: u64,
    /// Mean requests per dispatch (`completed / batch_launches`); 1.0 when
    /// batching is off or never coalesced anything.
    pub batch_occupancy: f64,
    pub metrics: RunMetrics,
    /// Per-worker breakdown (one entry per worker on multi-worker runs;
    /// single entry otherwise).
    pub per_worker: Vec<WorkerReport>,
    /// Captured `(request id, outputs)` pairs, ascending by id; empty
    /// unless `ServeOptions::capture_outputs` was set.
    pub outputs: Vec<(u64, Vec<Tensor>)>,
}

/// Nearest-rank percentile over an ascending-sorted sample: the smallest
/// value with at least `q·n` samples at or below it (`sorted[⌈q·n⌉ − 1]`).
/// The previous `((n−1)·q) as usize` pick *floored*, which collapsed p99
/// onto p95 for small streams and systematically understated tails.
fn nearest_rank(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

impl ServeReport {
    fn from_completions(
        mut lat: Vec<Completion>,
        wall: Duration,
        metrics: RunMetrics,
        per_worker: Vec<WorkerReport>,
        launches: usize,
    ) -> ServeReport {
        let mut outputs: Vec<(u64, Vec<Tensor>)> =
            lat.iter_mut().filter_map(|c| c.outputs.take().map(|o| (c.id, o))).collect();
        outputs.sort_by_key(|&(id, _)| id);
        if lat.is_empty() {
            return ServeReport { wall, metrics, per_worker, outputs, ..Default::default() };
        }
        let mut latencies: Vec<Duration> = lat.iter().map(|c| c.latency).collect();
        latencies.sort_unstable();
        let mut queue: Vec<Duration> = lat.iter().map(|c| c.queue_delay).collect();
        queue.sort_unstable();
        let mean = latencies.iter().sum::<Duration>() / latencies.len() as u32;
        ServeReport {
            completed: lat.len(),
            wall,
            p50: nearest_rank(&latencies, 0.50),
            p95: nearest_rank(&latencies, 0.95),
            p99: nearest_rank(&latencies, 0.99),
            mean,
            queue_p50: nearest_rank(&queue, 0.50),
            queue_p99: nearest_rank(&queue, 0.99),
            throughput_rps: lat.len() as f64 / wall.as_secs_f64().max(1e-9),
            batch_launches: launches,
            batched_requests: metrics.batched_requests,
            batch_occupancy: lat.len() as f64 / launches.max(1) as f64,
            metrics,
            per_worker,
            outputs,
        }
    }
}

/// Drive a compiled model over a pre-generated request stream, closed-loop
/// (back-to-back, as the paper's inference measurements are).
pub fn serve_closed_loop(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
) -> Result<ServeReport> {
    let start = Instant::now();
    let n = stream.len();
    let mut completions = Vec::with_capacity(n);
    let mut metrics = RunMetrics::default();
    for (i, inputs) in stream.into_iter().enumerate() {
        let t0 = Instant::now();
        let out = model.run(&inputs)?;
        metrics += &out.metrics;
        completions.push(Completion {
            id: i as u64,
            latency: t0.elapsed(),
            queue_delay: Duration::ZERO,
            outputs: None,
        });
    }
    Ok(ServeReport::from_completions(completions, start.elapsed(), metrics, Vec::new(), n))
}

/// Spawn the open-loop producer: absolute-deadline scheduling (the gap is
/// added to the *previous deadline*, never to "now", so per-send overhead
/// cannot accumulate into the offered rate) with optional on/off bursts.
///
/// Admission is non-blocking (`try_send`): a full queue **sheds** the
/// request instead of stalling the producer — a blocked send would push
/// every later arrival past its absolute deadline and quietly turn the
/// offered rate into the service rate, hiding the very overload an open
/// loop exists to expose. Returns the number of requests shed this way
/// (plus any the stream could never offer because every consumer died).
fn spawn_producer(
    tx: mpsc::SyncSender<Request>,
    stream: Vec<Vec<Tensor>>,
    rate_rps: f64,
    arrival: Arrival,
    deadline: Option<Duration>,
) -> std::thread::JoinHandle<u64> {
    std::thread::spawn(move || {
        let gap = Duration::from_secs_f64(1.0 / rate_rps.max(1e-3));
        let burst = match arrival {
            Arrival::Uniform => 1,
            Arrival::Bursty { burst } => burst.max(1),
        };
        let n = stream.len();
        let mut next_deadline = Instant::now();
        let mut shed = 0u64;
        for (i, inputs) in stream.into_iter().enumerate() {
            // Burst heads wait for their deadline; the rest of the burst
            // goes back-to-back. Advancing the deadline by `gap` per
            // request keeps the average offered rate exact in both modes.
            if i % burst == 0 {
                let now = Instant::now();
                if next_deadline > now {
                    std::thread::sleep(next_deadline - now);
                }
            }
            next_deadline += gap;
            let arrived = Instant::now();
            let req = Request {
                id: i as u64,
                inputs,
                arrived,
                deadline: deadline.map(|d| arrived + d),
                requeues: 0,
            };
            match tx.try_send(req) {
                Ok(()) => {}
                Err(mpsc::TrySendError::Full(_)) => shed += 1,
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    // Consumers died (error path): the rest of the stream
                    // can never be served — account it as shed so the
                    // caller's request reconciliation still balances.
                    return shed + (n - i) as u64;
                }
            }
        }
        shed
    })
}

/// A request stashed during batch assembly, with its grouping key and
/// leading extent computed exactly once (keying binds a full symbol
/// environment, so recomputing it per assembly pass would put redundant
/// shape work on the hot path).
struct Stashed {
    req: Request,
    tag: Option<(BatchKey, i64)>,
}

/// Would adding a member of extent `ext` keep the collected extents a
/// sub-multiset of the target group shape? (`None` target: always.)
fn fits_target(have: &[i64], ext: i64, target: Option<&[i64]>) -> bool {
    match target {
        None => true,
        Some(t) => {
            let need = t.iter().filter(|&&x| x == ext).count();
            let got = have.iter().filter(|&&x| x == ext).count();
            got < need
        }
    }
}

/// Do the collected extents reproduce the target group shape exactly?
fn matches_target(have: &[i64], target: Option<&[i64]>) -> bool {
    match target {
        None => false,
        Some(t) => {
            if have.len() != t.len() {
                return false;
            }
            let mut h = have.to_vec();
            h.sort_unstable();
            h == t
        }
    }
}

/// Assemble one dispatch group around `head`: matching requests stashed in
/// `pending` first, then a greedy drain of the shared queue, then (window
/// permitting) a bounded poll for stragglers. Non-matching requests land
/// in `pending` for a later dispatch; the caller serves `pending` in FIFO
/// order before blocking on the queue again, so nothing starves.
///
/// `target`, when set, is the **sorted extent multiset of a group shape
/// this worker already dispatched** (and therefore recorded a batch plan
/// for): assembly then prefers members that reproduce that shape and
/// stops the moment it does — a replayable group beats a larger
/// never-seen one — while members that would overflow the shape are
/// stashed to head their own group later. Returns the batch plus the
/// sorted extents it collected (empty for solo dispatches).
///
/// `next` must poll the queue WITHOUT blocking — the straggler window is
/// waited out here with short sleeps between polls, so a worker never
/// holds a shared receiver lock across the window (that would stall every
/// sibling worker's dequeue for the whole wait). Requests without a key
/// (batching off for them, or unbindable inputs) always dispatch solo.
#[allow(clippy::too_many_arguments)]
fn assemble_batch(
    head: Request,
    head_tag: Option<(BatchKey, i64)>,
    pending: &mut VecDeque<Stashed>,
    max_batch: usize,
    window: Duration,
    target: Option<&[i64]>,
    key_of: &mut dyn FnMut(&Request) -> Option<(BatchKey, i64)>,
    next: &mut dyn FnMut() -> Option<Request>,
) -> (Vec<Request>, Vec<i64>) {
    let (key, head_ext) = match head_tag {
        Some(t) if max_batch > 1 => t,
        _ => return (vec![head], Vec::new()),
    };
    // A remembered shape the head itself cannot belong to is stale for
    // this assembly (traffic moved on): ignore it rather than let it
    // block every candidate from joining.
    let target = target.filter(|t| t.iter().any(|&x| x == head_ext));
    let mut batch = vec![head];
    let mut have = vec![head_ext];
    let mut i = 0;
    while batch.len() < max_batch && !matches_target(&have, target) && i < pending.len() {
        let joins = match &pending[i].tag {
            Some((k, e)) => *k == key && fits_target(&have, *e, target),
            None => false,
        };
        if joins {
            if let Some(s) = pending.remove(i) {
                have.push(s.tag.expect("matched on tag").1);
                batch.push(s.req);
            }
        } else {
            i += 1;
        }
    }
    // The straggler window starts when the queue first runs dry (the
    // documented semantics) — greedy draining of an already-deep queue
    // must not eat into it.
    let mut deadline: Option<Instant> = None;
    while batch.len() < max_batch && !matches_target(&have, target) {
        match next() {
            Some(r) => {
                let tag = key_of(&r);
                match &tag {
                    Some((k, e)) if *k == key && fits_target(&have, *e, target) => {
                        have.push(*e);
                        batch.push(r);
                    }
                    _ => pending.push_back(Stashed { req: r, tag }),
                }
            }
            None => {
                // Queue ran dry: poll out the batching window (if any),
                // sleeping in short slices so nothing is held locked.
                let now = Instant::now();
                let dl = *deadline.get_or_insert(now + window);
                if now >= dl {
                    break;
                }
                std::thread::sleep((dl - now).min(Duration::from_micros(50)));
            }
        }
    }
    // The planned shape did not re-form (traffic shifted): fall back to a
    // plain greedy fill from the same-key stash so the target can never
    // pin this key to solo dispatches — the dispatched shape then
    // OVERWRITES the remembered one, adapting the target to the traffic.
    if target.is_some() && batch.len() < max_batch && !matches_target(&have, target) {
        let mut i = 0;
        while batch.len() < max_batch && i < pending.len() {
            let joins = matches!(&pending[i].tag, Some((k, _)) if *k == key);
            if joins {
                if let Some(s) = pending.remove(i) {
                    have.push(s.tag.expect("matched on tag").1);
                    batch.push(s.req);
                }
            } else {
                i += 1;
            }
        }
    }
    have.sort_unstable();
    (batch, have)
}

/// The shared drain-assemble-dispatch loop body: serve every request the
/// queue delivers (plus locally stashed ones), batching where `key_of`
/// allows, until the queue disconnects and the stash is empty.
///
/// The loop remembers the extent multiset of every group it successfully
/// dispatched batched (per grouping key — exactly the shapes the executor
/// recorded batch plans for) and feeds it to `assemble_batch` as the
/// target, so bursty repeat traffic re-forms replayable group shapes
/// instead of accreting never-seen ones.
///
/// Robustness: requests whose deadline passed while queued are shed at
/// dispatch admission (`deadline_misses`), never run. `run` is a
/// *supervised* dispatch — its outer `Err` means the dispatch panicked
/// (and the caller already swapped in a fresh executor): the in-flight
/// batch is requeued onto the local stash, bounded per request by
/// `opts.max_requeues` (past it, the request is shed), and the restart is
/// counted in `worker_restarts`.
#[allow(clippy::too_many_arguments)]
fn drain_queue(
    opts: &ServeOptions,
    completions: &mut Vec<Completion>,
    metrics: &mut RunMetrics,
    launches: &mut usize,
    key_of: &mut dyn FnMut(&Request) -> Option<(BatchKey, i64)>,
    next: &mut dyn FnMut() -> Option<Request>,
    recv_blocking: &mut dyn FnMut() -> Option<Request>,
    run: &mut dyn FnMut(&[Vec<Tensor>]) -> DispatchResult,
) -> Result<()> {
    let mut pending: VecDeque<Stashed> = VecDeque::new();
    let mut planned_shapes: HashMap<BatchKey, Vec<i64>> = HashMap::new();
    loop {
        let (head, head_tag) = match pending.pop_front() {
            Some(s) => (s.req, s.tag),
            None => match recv_blocking() {
                Some(r) => {
                    let k = key_of(&r);
                    (r, k)
                }
                None => break,
            },
        };
        let group = head_tag.as_ref().map(|(k, _)| k.clone());
        let target = group.as_ref().and_then(|k| planned_shapes.get(k)).cloned();
        let (batch, shape) = assemble_batch(
            head,
            head_tag,
            &mut pending,
            opts.max_batch,
            opts.batch_window,
            target.as_deref(),
            key_of,
            next,
        );
        // Admission control: a request whose deadline passed while it sat
        // queued (or stashed, or requeued) is shed here, not run — serving
        // it uselessly late only delays the still-live ones behind it.
        let now = Instant::now();
        let mut expired = 0u64;
        let batch: Vec<Request> = batch
            .into_iter()
            .filter(|r| match r.deadline {
                Some(d) if now >= d => {
                    expired += 1;
                    false
                }
                _ => true,
            })
            .collect();
        metrics.deadline_misses += expired;
        if batch.is_empty() {
            continue;
        }
        let delays: Vec<Duration> = batch.iter().map(|r| r.arrived.elapsed()).collect();
        let metas: Vec<(u64, Instant, Option<Instant>, u32)> =
            batch.iter().map(|r| (r.id, r.arrived, r.deadline, r.requeues)).collect();
        let inputs: Vec<Vec<Tensor>> = batch.into_iter().map(|r| r.inputs).collect();
        let t0 = Instant::now();
        match run(&inputs) {
            Ok(Ok(out)) => {
                let dt = t0.elapsed();
                *launches += 1;
                *metrics += &out.metrics;
                if expired == 0 && shape.len() > 1 && out.metrics.batched_launches > 0 {
                    if let Some(k) = group {
                        // The executor stacked (and on first sight planned)
                        // this group shape: steer later assemblies back to
                        // it. (Shedding changed the dispatched shape, so an
                        // expired member suppresses the recording.)
                        planned_shapes.insert(k, shape);
                    }
                }
                let mut outs = out.outputs.into_iter();
                for (j, (id, ..)) in metas.into_iter().enumerate() {
                    let produced = outs.next();
                    completions.push(Completion {
                        id,
                        latency: delays[j] + dt,
                        queue_delay: delays[j],
                        outputs: if opts.capture_outputs { produced } else { None },
                    });
                }
            }
            Ok(Err(e)) => return Err(e),
            Err(_panicked) => {
                // The dispatch panicked; `run` already replaced the
                // executor. Requeue the in-flight batch onto the local
                // stash (retried before the next queue dequeue), shedding
                // members that burned their whole requeue budget.
                metrics.worker_restarts += 1;
                for ((id, arrived, deadline, requeues), ins) in metas.into_iter().zip(inputs) {
                    if requeues >= opts.max_requeues {
                        metrics.shed_requests += 1;
                        continue;
                    }
                    let req =
                        Request { id, inputs: ins, arrived, deadline, requeues: requeues + 1 };
                    let tag = key_of(&req);
                    pending.push_back(Stashed { req, tag });
                }
            }
        }
    }
    Ok(())
}

/// Open-loop serving: a producer thread feeds one bounded queue at the
/// offered rate while `opts.workers` executor threads drain it. Queue
/// delay shows up in latency, as in a real deployment.
///
/// With `workers == 1` the calling thread drains the queue against the
/// model directly (any backend). With more, sibling executors are forked
/// from the model (see [`CompiledModel::fork_workers`]): per-worker plan
/// caches, shared kernel/weight stores — the compile-once, upload-once
/// serving engine. `max_batch > 1` turns on cross-request batching in
/// either shape (program backends; other backends always dispatch solo).
///
/// With `rebucket_interval` set, a background re-bucketing worker runs for
/// the duration of the serve call (stopped — and its in-flight cycle
/// joined — before this returns), and the report's metrics carry the
/// policy gauges (`policy_epoch`, `rebucket_swaps`, `extent_hist`).
pub fn serve_open_loop(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let rebucketer = opts
        .rebucket_interval
        .filter(|iv| !iv.is_zero())
        .and_then(|iv| spawn_rebucketer(model, iv, opts.max_buckets));
    let result = serve_open_loop_inner(model, stream, opts);
    if let Some(r) = rebucketer {
        r.stop();
    }
    let mut report = result?;
    fold_policy_metrics(model, &mut report.metrics);
    Ok(report)
}

fn serve_open_loop_inner(
    model: &mut CompiledModel,
    stream: Vec<Vec<Tensor>>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let n = stream.len();
    let faults = opts.faults.clone().or_else(FaultPlan::from_env);
    if opts.workers <= 1 {
        let (tx, rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
        let producer = spawn_producer(tx, stream, opts.rate_rps, opts.arrival, opts.deadline);
        let start = Instant::now();
        let mut completions = Vec::with_capacity(n);
        let mut metrics = RunMetrics::default();
        let mut launches = 0usize;
        let ctx: Option<(Arc<Program>, Arc<BatchAnalysis>)> =
            if opts.max_batch > 1 { model.batch_context() } else { None };
        let mut key_of = |req: &Request| {
            ctx.as_ref().and_then(|(p, a)| group_key_extent(&p.module, a, &req.inputs))
        };
        let mut next = || rx.try_recv().ok();
        let mut recv_blocking = || rx.recv().ok();
        let mut run = |inputs: &[Vec<Tensor>]| {
            let r = catch_unwind(AssertUnwindSafe(|| {
                if let Some(f) = &faults {
                    if f.should_fail(FaultSite::WorkerPanic) {
                        panic!("injected panic fault (worker dispatch)");
                    }
                }
                model.run_batch(inputs)
            }));
            if r.is_err() {
                model.restart_worker();
            }
            r
        };
        drain_queue(
            opts,
            &mut completions,
            &mut metrics,
            &mut launches,
            &mut key_of,
            &mut next,
            &mut recv_blocking,
            &mut run,
        )?;
        metrics.shed_requests += producer.join().unwrap_or(0);
        reconcile(&completions, &metrics, n)?;
        let wall = start.elapsed();
        let per_worker =
            vec![WorkerReport::summarize(0, &completions, launches, metrics.clone())];
        return Ok(ServeReport::from_completions(completions, wall, metrics, per_worker, launches));
    }

    // Multi-worker: fork sibling executors and drain the shared queue.
    let (prog, workers) = model.fork_workers(opts.workers)?;
    let (tx, rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
    let rx = Arc::new(Mutex::new(rx));
    let producer = spawn_producer(tx, stream, opts.rate_rps, opts.arrival, opts.deadline);
    let start = Instant::now();

    type WorkerResult = Result<(usize, Vec<Completion>, usize, RunMetrics)>;
    let handles: Vec<_> = workers
        .into_iter()
        .enumerate()
        .map(|(wi, mut exec)| {
            let rx = rx.clone();
            let prog = prog.clone();
            let opts = opts.clone();
            let faults = faults.clone();
            std::thread::Builder::new()
                .name(format!("disc-worker-{wi}"))
                .spawn(move || -> WorkerResult {
                    let mut completions = Vec::new();
                    let mut metrics = RunMetrics::default();
                    let mut launches = 0usize;
                    let analysis = if opts.max_batch > 1 {
                        Some(exec.batch_analysis(&prog))
                    } else {
                        None
                    };
                    let mut key_of = |req: &Request| {
                        analysis
                            .as_ref()
                            .and_then(|a| group_key_extent(&prog.module, a, &req.inputs))
                    };
                    // Hold the receiver lock only for a non-blocking poll
                    // or a dequeue; the (long) dispatch — and the batch
                    // straggler window — happen outside it. A sibling that
                    // panicked while holding the lock poisons nothing
                    // worth honoring (`util::relock`): the protected state
                    // is just the receiver, valid regardless of who unwound.
                    let mut next = || crate::util::relock(&rx).try_recv().ok();
                    let mut recv_blocking = || crate::util::relock(&rx).recv().ok();
                    let mut run = |inputs: &[Vec<Tensor>]| {
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            if let Some(f) = &faults {
                                if f.should_fail(FaultSite::WorkerPanic) {
                                    panic!("injected panic fault (worker {wi} dispatch)");
                                }
                            }
                            exec.run_batch(&prog, inputs)
                                .with_context(|| format!("worker {wi}"))
                        }));
                        if r.is_err() {
                            // The unwound dispatch left this executor's
                            // per-worker state suspect: replace it with a
                            // freshly forked sibling (shared stores, fresh
                            // plan caches and arena).
                            let fresh = exec.fork();
                            exec = fresh;
                        }
                        r
                    };
                    drain_queue(
                        &opts,
                        &mut completions,
                        &mut metrics,
                        &mut launches,
                        &mut key_of,
                        &mut next,
                        &mut recv_blocking,
                        &mut run,
                    )?;
                    Ok((wi, completions, launches, metrics))
                })
                .expect("spawning worker thread")
        })
        .collect();

    let mut completions: Vec<Completion> = Vec::with_capacity(n);
    let mut metrics = RunMetrics::default();
    let mut launches = 0usize;
    let mut per_worker: Vec<WorkerReport> = Vec::with_capacity(handles.len());
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok((wi, comps, wl, m))) => {
                per_worker.push(WorkerReport::summarize(wi, &comps, wl, m.clone()));
                metrics += &m;
                launches += wl;
                completions.extend(comps);
            }
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            // A worker died *outside* the supervised dispatch (queue
            // plumbing, assembly): surface it as an error instead of
            // propagating the panic through the coordinator.
            Err(_) => {
                first_err = first_err
                    .or_else(|| Some(anyhow::anyhow!("worker thread panicked outside dispatch")));
            }
        }
    }
    // Workers have exited (normally when the producer closed the queue, or
    // on error). Dropping our receiver handle disconnects a producer whose
    // sends can then never be consumed after an all-workers failure, so
    // the join below cannot deadlock.
    drop(rx);
    let producer_shed = producer.join().unwrap_or(0);
    if let Some(e) = first_err {
        return Err(e);
    }
    metrics.shed_requests += producer_shed;
    reconcile(&completions, &metrics, n)?;
    let wall = start.elapsed();
    per_worker.sort_by_key(|w| w.worker);
    Ok(ServeReport::from_completions(completions, wall, metrics, per_worker, launches))
}

/// The zero-lost-requests invariant: every offered request is completed,
/// shed, or deadline-missed — nothing silently disappears, with faults
/// injected or not.
fn reconcile(completions: &[Completion], metrics: &RunMetrics, n: usize) -> Result<()> {
    let accounted =
        completions.len() as u64 + metrics.shed_requests + metrics.deadline_misses;
    anyhow::ensure!(
        accounted == n as u64,
        "lost requests: {} completed + {} shed + {} deadline-missed != {n} offered",
        completions.len(),
        metrics.shed_requests,
        metrics.deadline_misses
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};

    fn small_model() -> CompiledModel {
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap()
    }

    #[test]
    fn closed_loop_serves_stream() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(8, 42);
        let report = serve_closed_loop(&mut model, stream).unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p95 >= report.p50);
        assert!(report.metrics.mem_kernels > 0);
        assert_eq!(report.batch_launches, 8, "closed loop dispatches solo");
        assert_eq!(report.batch_occupancy, 1.0);
    }

    #[test]
    fn open_loop_includes_queue_delay() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(5, 43);
        let report = serve_open_loop(&mut model, stream, &ServeOptions::rate(200.0)).unwrap();
        assert_eq!(report.completed, 5);
        assert!(report.mean > Duration::ZERO);
        assert_eq!(report.per_worker.len(), 1);
        assert_eq!(report.batch_launches, 5, "batching off: one dispatch per request");
        assert_eq!(report.per_worker[0].launches, 5);
        assert!(report.outputs.is_empty(), "outputs kept only on request");
    }

    #[test]
    fn multi_worker_open_loop_completes_and_aggregates() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(12, 44);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(5_000.0).workers(3),
        )
        .unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.per_worker.len(), 3);
        assert_eq!(report.per_worker.iter().map(|wr| wr.completed).sum::<usize>(), 12);
        assert_eq!(
            report.per_worker.iter().map(|wr| wr.launches).sum::<usize>(),
            report.batch_launches
        );
        assert!(report.metrics.mem_kernels > 0, "metrics aggregate across workers");
    }

    #[test]
    fn multi_worker_requires_program_backend() {
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Eager)).unwrap();
        let err = serve_open_loop(
            &mut model,
            w.request_stream(2, 45),
            &ServeOptions::rate(100.0).workers(2),
        );
        assert!(err.is_err(), "eager backend cannot fork workers");
    }

    #[test]
    fn bursty_arrival_completes_the_stream() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(9, 46);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(3_000.0).workers(2).bursty(4),
        )
        .unwrap();
        assert_eq!(report.completed, 9);
        assert!(report.queue_p99 >= report.queue_p50);
    }

    #[test]
    fn batching_options_compose() {
        let o = ServeOptions::rate(10.0).workers(2).batch(8).batch_window_us(250).keep_outputs();
        assert_eq!(o.max_batch, 8);
        assert_eq!(o.batch_window, Duration::from_micros(250));
        assert!(o.capture_outputs);
        // Degenerate values clamp to "off".
        assert_eq!(ServeOptions::rate(1.0).batch(0).max_batch, 1);
        // Robustness knobs.
        let o = ServeOptions::rate(10.0).deadline_ms(5).max_requeues(7);
        assert_eq!(o.deadline, Some(Duration::from_millis(5)));
        assert_eq!(o.max_requeues, 7);
        assert!(o.faults.is_none());
    }

    #[test]
    fn worker_panic_requeues_and_restarts() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        // The first dispatch panics (injected); the interrupted request
        // must be requeued and served by the restarted worker — nothing
        // lost, one restart on the books.
        let faults = Arc::new(FaultPlan::parse("seed=9,panic=1000:1").unwrap());
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(5, 49);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(100_000.0).faults(faults.clone()),
        )
        .unwrap();
        assert_eq!(report.completed, 5, "the panicked dispatch must be requeued, not lost");
        assert_eq!(report.metrics.worker_restarts, 1);
        assert_eq!(report.metrics.shed_requests, 0);
        assert_eq!(report.metrics.deadline_misses, 0);
        assert_eq!(faults.fired(FaultSite::WorkerPanic), 1);
    }

    #[test]
    fn multi_worker_panics_requeue_across_restarts() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        // Two injected panics across a shared 3-worker pool: every request
        // still completes (requeue budget 2 covers a request hit twice)
        // and each panic shows up as exactly one worker restart.
        let faults = Arc::new(FaultPlan::parse("seed=10,panic=1000:2").unwrap());
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(12, 50);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(50_000.0).workers(3).faults(faults.clone()),
        )
        .unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.metrics.worker_restarts, 2);
        assert_eq!(report.metrics.shed_requests, 0);
        assert_eq!(faults.fired(FaultSite::WorkerPanic), 2);
    }

    #[test]
    fn exhausted_requeue_budget_sheds_instead_of_looping() {
        use crate::runtime::faults::FaultPlan;
        // Every dispatch panics (unlimited injection) and the budget is
        // zero: each request is shed after its first interrupted dispatch.
        // The stream still terminates and the accounting balances.
        let faults = Arc::new(FaultPlan::parse("seed=11,panic=1000").unwrap());
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(4, 51);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(100_000.0).max_requeues(0).faults(faults),
        )
        .unwrap();
        assert_eq!(report.completed, 0);
        assert_eq!(report.metrics.shed_requests, 4);
        assert_eq!(report.metrics.worker_restarts, 4, "one restart per interrupted dispatch");
    }

    #[test]
    fn expired_requests_are_shed_not_served() {
        // A zero deadline expires every request the moment it arrives:
        // admission control sheds the whole stream as deadline misses and
        // the reconciliation still balances.
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(4, 52);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(100_000.0).deadline_ms(0),
        )
        .unwrap();
        assert_eq!(report.completed, 0);
        assert_eq!(report.metrics.deadline_misses, 4);
        assert_eq!(report.batch_launches, 0, "expired requests never dispatch");
        // A generous deadline sheds nothing.
        let stream = w.request_stream(4, 53);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(100_000.0).deadline_ms(60_000),
        )
        .unwrap();
        assert_eq!(report.completed, 4);
        assert_eq!(report.metrics.deadline_misses, 0);
    }

    #[test]
    fn full_queue_sheds_instead_of_blocking_the_producer() {
        // queue_cap 1 with an effectively instantaneous offered stream:
        // the producer must shed (not block), and completed + shed must
        // reconcile to the stream length.
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(32, 54);
        let mut opts = ServeOptions::rate(1e9);
        opts.queue_cap = 1;
        let report = serve_open_loop(&mut model, stream, &opts).unwrap();
        assert!(report.metrics.shed_requests >= 1, "a 1-deep queue under flood must shed");
        assert_eq!(report.completed as u64 + report.metrics.shed_requests, 32);
        assert!(report.completed >= 1, "the drained head must still be served");
    }

    #[test]
    fn batching_on_ineligible_program_serves_solo() {
        // TTS has a static-leading parameter (`prev_frame: [1, MEL]`), so
        // the analysis rejects it and every dispatch stays solo — the
        // fallback path the coordinator must keep correct.
        let mut model = small_model();
        let ctx = model.batch_context();
        assert!(ctx.is_some(), "program backend always yields a context");
        let (_, analysis) = ctx.unwrap();
        assert!(!analysis.eligible(), "tts must be batching-ineligible");
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(6, 47);
        let report = serve_open_loop(
            &mut model,
            stream,
            &ServeOptions::rate(50_000.0).batch(4).keep_outputs(),
        )
        .unwrap();
        assert_eq!(report.completed, 6);
        assert_eq!(report.batch_launches, 6, "ineligible program never batches");
        assert_eq!(report.batched_requests, 0);
        assert_eq!(report.batch_occupancy, 1.0);
        assert_eq!(report.outputs.len(), 6, "outputs captured per request");
    }

    #[test]
    fn rebucketing_serve_stays_bit_exact_and_reports_policy_gauges() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(10, 55);
        let report = serve_open_loop(
            &mut model,
            stream.clone(),
            &ServeOptions::rate(2_000.0).rebucket_every_ms(1).max_buckets(4).keep_outputs(),
        )
        .unwrap();
        assert_eq!(report.completed, 10);
        assert!(!report.metrics.extent_hist.is_empty(), "policy gauges must be reported");
        // Whether or not a swap landed mid-stream (timing-dependent), every
        // output must match a fresh model's solo run bit-for-bit.
        let mut fresh = small_model();
        for (id, got) in &report.outputs {
            let want = fresh.run(&stream[*id as usize]).unwrap().outputs;
            assert_eq!(got, &want, "request {id} diverged under re-bucketing");
        }
        // Options compose; 0 turns the loop off.
        let o = ServeOptions::rate(1.0).rebucket_every_ms(250).max_buckets(6);
        assert_eq!(o.rebucket_interval, Some(Duration::from_millis(250)));
        assert_eq!(o.max_buckets, 6);
        assert_eq!(ServeOptions::rate(1.0).rebucket_every_ms(0).rebucket_interval, None);
    }

    #[test]
    fn capture_outputs_match_direct_runs() {
        let mut model = small_model();
        let w = crate::workloads::tts::workload();
        let stream = w.request_stream(4, 48);
        let report = serve_open_loop(
            &mut model,
            stream.clone(),
            &ServeOptions::rate(1_000.0).keep_outputs(),
        )
        .unwrap();
        assert_eq!(report.outputs.len(), 4);
        let mut fresh = small_model();
        for (i, inputs) in stream.iter().enumerate() {
            let want = fresh.run(inputs).unwrap().outputs;
            let (id, got) = &report.outputs[i];
            assert_eq!(*id, i as u64, "outputs sorted by request id");
            assert_eq!(got, &want, "captured outputs diverged at request {i}");
        }
    }

    #[test]
    fn nearest_rank_percentiles_do_not_collapse_tails() {
        // 100 distinct latencies 1..=100 ms.
        let mk = |ms: u64| Duration::from_millis(ms);
        let sorted: Vec<Duration> = (1..=100).map(mk).collect();
        assert_eq!(nearest_rank(&sorted, 0.50), mk(50));
        assert_eq!(nearest_rank(&sorted, 0.95), mk(95));
        assert_eq!(nearest_rank(&sorted, 0.99), mk(99));
        assert_eq!(nearest_rank(&sorted, 1.0), mk(100));
        // Small stream: p99 is the max (the floored pick used to report
        // the 9th of 10 samples for BOTH p95 and p99, understating the
        // tail; the old formula gave index 8 = 9ms here).
        let small: Vec<Duration> = (1..=10).map(mk).collect();
        assert_eq!(nearest_rank(&small, 0.99), mk(10));
        assert_eq!(nearest_rank(&small, 0.50), mk(5));
        // Degenerate cases.
        assert_eq!(nearest_rank(&[], 0.99), Duration::ZERO);
        assert_eq!(nearest_rank(&[mk(7)], 0.01), mk(7));
    }

    #[test]
    fn producer_deadline_scheduling_holds_offered_rate() {
        // 30 requests at 1 kHz must take ~30ms of producer time, not
        // 30×(gap + per-send overhead). Generous upper bound for CI noise;
        // the old sleep-after-send producer also always passed the lower
        // bound, so the assertion that catches the drift bug is the upper.
        let (tx, rx) = mpsc::sync_channel::<Request>(64);
        let stream: Vec<Vec<Tensor>> = (0..30).map(|_| Vec::new()).collect();
        let t0 = Instant::now();
        let h = spawn_producer(tx, stream, 1_000.0, Arrival::Uniform, None);
        let mut got = 0;
        while rx.recv().is_ok() {
            got += 1;
        }
        let shed = h.join().unwrap();
        let took = t0.elapsed();
        assert_eq!(got, 30);
        assert_eq!(shed, 0, "a drained queue never sheds");
        assert!(took >= Duration::from_millis(25), "offered faster than the rate: {took:?}");
        assert!(took <= Duration::from_millis(250), "producer drifted: {took:?}");
    }

    #[test]
    fn assemble_batch_groups_by_key_and_respects_the_cap() {
        // Synthetic requests: key = number of input tensors (0 vs 1).
        let mk = |id: u64, n_inputs: usize| Request {
            id,
            inputs: (0..n_inputs).map(|_| Tensor::scalar_f32(0.0)).collect(),
            arrived: Instant::now(),
            deadline: None,
            requeues: 0,
        };
        let key_for = |r: &Request| {
            Some((
                BatchKey { residual: vec![(crate::shape::SymId(0), r.inputs.len() as i64)] },
                1i64,
            ))
        };
        let stash = |r: Request| {
            let tag = key_for(&r);
            Stashed { req: r, tag }
        };
        let mut pending: VecDeque<Stashed> = VecDeque::new();
        pending.push_back(stash(mk(1, 1))); // other group: stays pending
        pending.push_back(stash(mk(2, 0))); // same group: joins
        let mut queued = VecDeque::from([mk(3, 0), mk(4, 1), mk(5, 0), mk(6, 0)]);
        let mut key_of = |r: &Request| {
            Some((
                BatchKey { residual: vec![(crate::shape::SymId(0), r.inputs.len() as i64)] },
                1i64,
            ))
        };
        let mut next = || queued.pop_front();
        let head = mk(0, 0);
        let head_tag = key_for(&head);
        let (batch, shape) = assemble_batch(
            head,
            head_tag,
            &mut pending,
            4,
            Duration::ZERO,
            None,
            &mut key_of,
            &mut next,
        );
        // Head 0 + pending 2 + queued 3, 5 — capped at 4, id 4 stashed.
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3, 5]);
        assert_eq!(shape, vec![1, 1, 1, 1], "collected extents reported");
        let stashed: Vec<u64> = pending.iter().map(|s| s.req.id).collect();
        assert_eq!(stashed, vec![1, 4]);
        assert_eq!(queued.len(), 1, "assembly stopped at the cap");
    }

    #[test]
    fn assemble_batch_without_key_dispatches_solo() {
        let mk = |id: u64| Request { id, inputs: vec![], arrived: Instant::now(), deadline: None, requeues: 0 };
        let mut pending: VecDeque<Stashed> = VecDeque::new();
        let mut key_of = |_: &Request| None;
        let mut next = || -> Option<Request> {
            panic!("solo dispatch must not poll the queue")
        };
        let (batch, shape) = assemble_batch(
            mk(7),
            None,
            &mut pending,
            8,
            Duration::from_millis(50),
            None,
            &mut key_of,
            &mut next,
        );
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 7);
        assert!(shape.is_empty());
    }

    #[test]
    fn assemble_batch_steers_toward_planned_group_shapes() {
        // Same grouping key throughout; extents vary. A previously planned
        // shape [2, 3] must be reproduced exactly: the oversized extent-5
        // straggler is left pending, and assembly stops the moment the
        // multiset matches instead of greedily draining the queue.
        let key = BatchKey { residual: vec![(crate::shape::SymId(0), 64)] };
        let mk = |id: u64| Request { id, inputs: vec![], arrived: Instant::now(), deadline: None, requeues: 0 };
        let exts: HashMap<u64, i64> =
            [(0u64, 2i64), (1, 5), (2, 3), (3, 3), (4, 2)].into_iter().collect();
        let tag_of = |id: u64, exts: &HashMap<u64, i64>, key: &BatchKey| {
            Some((key.clone(), exts[&id]))
        };
        let mut pending: VecDeque<Stashed> = VecDeque::new();
        pending.push_back(Stashed { req: mk(1), tag: tag_of(1, &exts, &key) }); // ext 5
        pending.push_back(Stashed { req: mk(2), tag: tag_of(2, &exts, &key) }); // ext 3
        let mut queued = VecDeque::from([mk(3), mk(4)]);
        let exts2 = exts.clone();
        let key2 = key.clone();
        let mut key_of = move |r: &Request| tag_of(r.id, &exts2, &key2);
        let mut next = || queued.pop_front();
        let target = vec![2i64, 3];
        let (batch, shape) = assemble_batch(
            mk(0),
            tag_of(0, &exts, &key),
            &mut pending,
            8,
            Duration::ZERO,
            Some(&target),
            &mut key_of,
            &mut next,
        );
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2], "head (ext 2) + pending ext 3 reproduce the plan shape");
        assert_eq!(shape, target, "assembly stopped exactly at the planned shape");
        assert_eq!(pending.len(), 1, "the oversized straggler stays stashed");
        assert_eq!(pending[0].req.id, 1);
        assert_eq!(queued.len(), 2, "no queue drain past a matched shape");
    }

    #[test]
    fn stale_target_shapes_never_pin_a_key_to_solo_dispatches() {
        // Traffic moved on from the remembered shape: batching must still
        // coalesce (and the dispatched shape then overwrites the target).
        let key = BatchKey { residual: vec![(crate::shape::SymId(0), 64)] };
        let mk = |id: u64| Request { id, inputs: vec![], arrived: Instant::now(), deadline: None, requeues: 0 };

        // Head extent absent from the target: the target is ignored and
        // assembly is plain greedy.
        let mut pending: VecDeque<Stashed> = VecDeque::new();
        let k2 = key.clone();
        let mut key_of = move |_: &Request| Some((k2.clone(), 5i64));
        let mut queued = VecDeque::from([mk(1), mk(2)]);
        let mut next = || queued.pop_front();
        let target = vec![2i64, 3];
        let (batch, shape) = assemble_batch(
            mk(0),
            Some((key.clone(), 5)),
            &mut pending,
            4,
            Duration::ZERO,
            Some(&target),
            &mut key_of,
            &mut next,
        );
        assert_eq!(batch.len(), 3, "uniform ext-5 traffic must still batch");
        assert_eq!(shape, vec![5, 5, 5]);

        // Head fits but the rest of the shape never arrives: the window
        // expires and the same-key stash back-fills greedily.
        let mut pending: VecDeque<Stashed> = VecDeque::new();
        pending.push_back(Stashed { req: mk(11), tag: Some((key.clone(), 2)) });
        pending.push_back(Stashed { req: mk(12), tag: Some((key.clone(), 2)) });
        let k3 = key.clone();
        let mut key_of = move |_: &Request| Some((k3.clone(), 2i64));
        let mut next = || -> Option<Request> { None };
        let (batch, shape) = assemble_batch(
            mk(10),
            Some((key.clone(), 2)),
            &mut pending,
            4,
            Duration::ZERO,
            Some(&target),
            &mut key_of,
            &mut next,
        );
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 11, 12], "stash back-fills when the shape cannot re-form");
        assert_eq!(shape, vec![2, 2, 2]);
        assert!(pending.is_empty());
    }

    #[test]
    fn group_steering_stash_cannot_starve_a_non_matching_request() {
        // One group-B request arrives inside a long run of group-A traffic.
        // Assembly stashes it (it can't join A's group), but the drain loop
        // serves the stash FIFO as the *next head* — so B must dispatch in
        // the very next group, no matter how much A traffic keeps coming.
        let key_a = BatchKey { residual: vec![(crate::shape::SymId(0), 64)] };
        let key_b = BatchKey { residual: vec![(crate::shape::SymId(0), 96)] };
        let mk = |id: u64| Request {
            id,
            inputs: vec![],
            arrived: Instant::now(),
            deadline: None,
            requeues: 0,
        };
        let tag = |r: &Request| {
            let k = if r.id == 1 { key_b.clone() } else { key_a.clone() };
            Some((k, 1i64))
        };
        let mut queued: VecDeque<Request> = (0..20).map(mk).collect();
        let mut pending: VecDeque<Stashed> = VecDeque::new();
        let mut dispatches: Vec<Vec<u64>> = Vec::new();
        // The drain-loop head selection: stash FIFO first, then the queue.
        loop {
            let (head, head_tag) = match pending.pop_front() {
                Some(s) => (s.req, s.tag),
                None => match queued.pop_front() {
                    Some(r) => {
                        let t = tag(&r);
                        (r, t)
                    }
                    None => break,
                },
            };
            let mut key_of = |r: &Request| tag(r);
            let mut next = || queued.pop_front();
            let (batch, _shape) = assemble_batch(
                head,
                head_tag,
                &mut pending,
                4,
                Duration::ZERO,
                None,
                &mut key_of,
                &mut next,
            );
            dispatches.push(batch.iter().map(|r| r.id).collect());
        }
        let pos = dispatches
            .iter()
            .position(|d| d.contains(&1))
            .expect("the group-B request must dispatch");
        assert_eq!(pos, 1, "stashed non-matching request heads the next dispatch: {dispatches:?}");
        assert_eq!(dispatches[1], vec![1], "group B dispatches alone (nothing else matches)");
        // Zero-lost: every request dispatched exactly once.
        let mut all: Vec<u64> = dispatches.concat();
        all.sort_unstable();
        assert_eq!(all, (0..20).collect::<Vec<u64>>());
        assert!(pending.is_empty(), "the stash fully drains");
    }
}
