//! Multi-tenant serving with SLO bulkheads: N compiled models behind one
//! admission front, sharing the process-wide worker pool and stores while
//! staying *isolated* in every dimension that matters for deployment
//! (see docs/runtime.md §Multi-tenant serving & isolation).
//!
//! Bulkheads per tenant:
//!
//! * **Admission** — each tenant has its own bounded queue fed by its own
//!   open-loop producer; a flooding tenant fills (and sheds from) its own
//!   queue, never a neighbor's.
//! * **SLO class** — [`SloClass::Latency`] assembles greedily (zero
//!   straggler window), [`SloClass::Throughput`] trades latency for
//!   occupancy with a wide `batch_window`.
//! * **Weighted-fair dispatch** — every worker runs deficit round-robin
//!   over the tenants: each sweep tops a tenant's deficit up by its
//!   `weight` and serving a batch of k requests spends k, so a backlogged
//!   high-weight tenant gets proportionally more of the shared pool and an
//!   idle tenant's unused share never accumulates into a burst.
//! * **Cache arbitration** — all tenants compile through ONE
//!   [`DiscCompiler`] (shared kernel store: each pattern×bucket compiles
//!   once per process no matter how many tenants hit it; the kernel store
//!   is grow-only, so sharing needs no eviction policy), and the shared
//!   `WeightStore` honors per-tenant residency floors
//!   ([`TenantSpec::floor_bytes`]): one model's working set cannot evict
//!   another's below its guarantee.
//! * **Bucket policy** — each tenant's model owns its own
//!   [`crate::codegen::PolicySwitch`]; when adaptive re-bucketing is on
//!   ([`MixOptions::rebucket_every_ms`]) every tenant gets its own
//!   background loop re-deriving boundaries from its own extent
//!   histogram, so one tenant's length skew never reshapes a neighbor's
//!   bucket family (the compiled kernels still share the process-wide
//!   store).
//! * **Fault quarantine** — worker-panic faults are consulted only inside
//!   the [`TenantSpec::fault_target`] tenant's dispatches, so injected
//!   storms attribute to exactly one tenant; device-seam faults
//!   (compile/transfer/OOM) surface in the metrics of whichever tenant's
//!   dispatch fired them. Repeated consecutive failures trip that
//!   tenant's **circuit breaker**: Closed → Open (quarantine: requests are
//!   served by the host reference evaluator, or shed, per
//!   [`Quarantine`]) → HalfOpen (after `probe_after` quarantined
//!   dispatches, one probe runs a real dispatch; success re-closes,
//!   failure re-opens). Healthy tenants keep full replay-tier service
//!   throughout.
//!
//! The zero-lost invariant is reconciled **per tenant**: for every tenant,
//! `completed + shed + deadline_missed == offered` — a fault storm may
//! degrade its own tenant's answers or shed its requests, but nothing is
//! ever silently lost, and no other tenant's accounting moves.

use super::{
    assemble_batch, reconcile, spawn_producer, Arrival, Completion, Request, ServeReport,
    Stashed,
};
use crate::compiler::{CompileOptions, DiscCompiler, Mode};
use crate::dhlo::Module;
use crate::program::Program;
use crate::runtime::batching::group_key_extent;
use crate::runtime::executor::Executor;
use crate::runtime::faults::{FaultPlan, FaultSite};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::reference;
use crate::runtime::tensor::Tensor;
use crate::util::relock;
use crate::workloads;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A tenant's service-level objective class, mapped to batch-assembly
/// behavior: latency-bound tenants never wait for stragglers, throughput
/// tenants trade queueing delay for occupancy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloClass {
    Latency,
    Throughput,
}

impl SloClass {
    /// The straggler window batch assembly may wait out for this class.
    pub fn batch_window(self) -> Duration {
        match self {
            SloClass::Latency => Duration::ZERO,
            SloClass::Throughput => Duration::from_micros(400),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SloClass::Latency => "latency",
            SloClass::Throughput => "throughput",
        }
    }
}

/// What happens to a quarantined tenant's requests while its breaker is
/// open: serve them through the host reference evaluator (degraded but
/// answered — the bottom rung of the degradation ladder), or shed them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quarantine {
    Reference,
    Shed,
}

/// One tenant: a workload behind its own admission bulkhead.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Workload name (`workloads::by_name`).
    pub workload: String,
    pub slo: SloClass,
    /// Weighted-fair share of the worker pool (deficit round-robin
    /// quantum). Relative: a weight-4 tenant gets 4× the dispatch
    /// capacity of a weight-1 tenant when both are backlogged.
    pub weight: u32,
    /// Requests this tenant's producer offers.
    pub requests: usize,
    pub rate_rps: f64,
    /// Bound of this tenant's own queue (overflow sheds *its* requests).
    pub queue_cap: usize,
    pub deadline: Option<Duration>,
    /// Request-stream seed (deterministic per tenant).
    pub seed: u64,
    /// Weight-cache residency floor (bytes) arbitrated in the shared
    /// `WeightStore`; 0 reserves nothing.
    pub weight_floor_bytes: u64,
    pub arrival: Arrival,
    /// Arm worker-panic fault injection inside this tenant's dispatches
    /// (chaos gates). Exactly attributes the storm to this tenant.
    pub fault_target: bool,
}

impl TenantSpec {
    /// A latency-bound tenant: tight assembly, high fair-share weight.
    pub fn latency(name: &str, workload: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            workload: workload.to_string(),
            slo: SloClass::Latency,
            weight: 4,
            requests: 120,
            rate_rps: 200.0,
            queue_cap: 256,
            deadline: None,
            seed: 0xD15C_0001,
            weight_floor_bytes: 0,
            arrival: Arrival::Uniform,
            fault_target: false,
        }
    }

    /// A throughput-bound tenant: wide batch window, low weight — the
    /// classic "batch flood" neighbor.
    pub fn throughput(name: &str, workload: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            workload: workload.to_string(),
            slo: SloClass::Throughput,
            weight: 1,
            requests: 240,
            rate_rps: 400.0,
            queue_cap: 512,
            deadline: None,
            seed: 0xD15C_0002,
            weight_floor_bytes: 0,
            arrival: Arrival::Uniform,
            fault_target: false,
        }
    }

    pub fn requests(mut self, n: usize) -> TenantSpec {
        self.requests = n;
        self
    }

    pub fn rate(mut self, rps: f64) -> TenantSpec {
        self.rate_rps = rps;
        self
    }

    pub fn seed(mut self, seed: u64) -> TenantSpec {
        self.seed = seed;
        self
    }

    pub fn weight(mut self, w: u32) -> TenantSpec {
        self.weight = w.max(1);
        self
    }

    pub fn queue_cap(mut self, cap: usize) -> TenantSpec {
        self.queue_cap = cap.max(1);
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> TenantSpec {
        self.deadline = Some(Duration::from_millis(ms));
        self
    }

    pub fn floor_bytes(mut self, bytes: u64) -> TenantSpec {
        self.weight_floor_bytes = bytes;
        self
    }

    pub fn bursty(mut self, burst: usize) -> TenantSpec {
        self.arrival = Arrival::Bursty { burst: burst.max(1) };
        self
    }

    pub fn fault_target(mut self) -> TenantSpec {
        self.fault_target = true;
        self
    }
}

/// Knobs shared across the whole mix.
#[derive(Debug, Clone)]
pub struct MixOptions {
    /// Worker threads in the shared pool (each holds one forked executor
    /// per tenant).
    pub workers: usize,
    /// Cross-request batching bound, per dispatch (within one tenant —
    /// groups never mix tenants).
    pub max_batch: usize,
    /// Panic-driven requeues per request before it is shed.
    pub max_requeues: u32,
    /// Fault schedule (worker-panic consults for `fault_target` tenants;
    /// the device seams are armed on the shared device). `None` falls
    /// back to the `DISC_FAULTS` environment spec.
    pub faults: Option<Arc<FaultPlan>>,
    /// Consecutive dispatch failures that trip a tenant's breaker.
    pub breaker_threshold: u32,
    /// Quarantined dispatches observed before the breaker half-opens and
    /// sends one probe through the real path.
    pub probe_after: u64,
    pub quarantine: Quarantine,
    /// Keep per-request outputs in the per-tenant reports (bit-exactness
    /// gates).
    pub capture_outputs: bool,
    /// Byte budget for the shared weight store (`None` leaves it
    /// unbounded); per-tenant floors bound eviction from below.
    pub weight_budget_bytes: Option<u64>,
    /// Re-derive every tenant's bucket boundaries from its own traffic at
    /// this cadence (`None` disables adaptive re-bucketing). Each tenant
    /// has its own [`crate::codegen::PolicySwitch`], so one tenant's skew
    /// never reshapes a neighbor's buckets.
    pub rebucket_interval: Option<Duration>,
    /// Cut budget per symbol when re-deriving boundaries.
    pub max_buckets: usize,
}

impl Default for MixOptions {
    fn default() -> Self {
        MixOptions {
            workers: 2,
            max_batch: 4,
            max_requeues: 2,
            faults: None,
            breaker_threshold: 3,
            probe_after: 8,
            quarantine: Quarantine::Reference,
            capture_outputs: false,
            weight_budget_bytes: None,
            rebucket_interval: None,
            max_buckets: 8,
        }
    }
}

impl MixOptions {
    pub fn new() -> MixOptions {
        MixOptions::default()
    }

    pub fn workers(mut self, n: usize) -> MixOptions {
        self.workers = n.max(1);
        self
    }

    pub fn batch(mut self, max_batch: usize) -> MixOptions {
        self.max_batch = max_batch.max(1);
        self
    }

    pub fn max_requeues(mut self, n: u32) -> MixOptions {
        self.max_requeues = n;
        self
    }

    pub fn faults(mut self, plan: Arc<FaultPlan>) -> MixOptions {
        self.faults = Some(plan);
        self
    }

    pub fn breaker(mut self, threshold: u32, probe_after: u64) -> MixOptions {
        self.breaker_threshold = threshold.max(1);
        self.probe_after = probe_after;
        self
    }

    pub fn quarantine(mut self, q: Quarantine) -> MixOptions {
        self.quarantine = q;
        self
    }

    pub fn keep_outputs(mut self) -> MixOptions {
        self.capture_outputs = true;
        self
    }

    pub fn weight_budget(mut self, bytes: u64) -> MixOptions {
        self.weight_budget_bytes = Some(bytes);
        self
    }

    /// Enable traffic-adaptive re-bucketing for every tenant at this
    /// cadence (milliseconds; 0 disables).
    pub fn rebucket_every_ms(mut self, ms: u64) -> MixOptions {
        self.rebucket_interval = (ms > 0).then(|| Duration::from_millis(ms));
        self
    }

    /// Cut budget per symbol for derived boundaries.
    pub fn max_buckets(mut self, k: usize) -> MixOptions {
        self.max_buckets = k.max(1);
        self
    }
}

/// One tenant's slice of a mix run: its own latency distribution, its own
/// metrics (the per-tenant zero-lost invariant has already been checked
/// against `offered` when this exists), and its breaker history.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub slo: SloClass,
    pub offered: usize,
    /// Closed→Open breaker transitions (from `RunMetrics::breaker_trips`,
    /// surfaced here for gates).
    pub breaker_trips: u64,
    /// Probe dispatches sent while half-open.
    pub probes: u64,
    /// The tenant's serving report (percentiles, throughput, metrics,
    /// captured outputs), over the mix's wall clock.
    pub report: ServeReport,
}

/// Aggregate mix run report.
#[derive(Debug, Clone)]
pub struct MixReport {
    pub wall: Duration,
    /// Per-tenant slices, in spec order.
    pub tenants: Vec<TenantReport>,
    /// All tenants' metrics folded (`+=` semantics).
    pub aggregate: RunMetrics,
}

/// Per-tenant circuit breaker. Shared (one per tenant, behind a mutex)
/// across the worker pool, so consecutive failures observed by *different*
/// workers still trip it.
struct Breaker {
    threshold: u32,
    probe_after: u64,
    consecutive: u32,
    state: BreakerState,
    /// Quarantined dispatches observed since the breaker last opened.
    observed: u64,
    trips: u64,
    probes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// What the breaker lets one dispatch do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Gate {
    /// Normal service through the real executor.
    Real,
    /// Half-open probe: real dispatch; its outcome decides re-admission.
    Probe,
    /// Breaker open: serve via the quarantine policy.
    Quarantine,
}

impl Breaker {
    fn new(threshold: u32, probe_after: u64) -> Breaker {
        Breaker {
            threshold: threshold.max(1),
            probe_after,
            consecutive: 0,
            state: BreakerState::Closed,
            observed: 0,
            trips: 0,
            probes: 0,
        }
    }

    fn admit(&mut self) -> Gate {
        match self.state {
            BreakerState::Closed => Gate::Real,
            // A probe is already in flight on some worker; everyone else
            // keeps quarantining until it resolves.
            BreakerState::HalfOpen => Gate::Quarantine,
            BreakerState::Open => {
                self.observed += 1;
                if self.observed >= self.probe_after {
                    self.state = BreakerState::HalfOpen;
                    self.probes += 1;
                    Gate::Probe
                } else {
                    Gate::Quarantine
                }
            }
        }
    }

    fn on_success(&mut self, probe: bool) {
        self.consecutive = 0;
        if probe {
            self.state = BreakerState::Closed;
        }
    }

    fn on_failure(&mut self, probe: bool) {
        self.consecutive += 1;
        if probe {
            // Failed probe: back to quarantine, restart the probe clock.
            self.state = BreakerState::Open;
            self.observed = 0;
        } else if self.state == BreakerState::Closed && self.consecutive >= self.threshold {
            self.state = BreakerState::Open;
            self.observed = 0;
            self.trips += 1;
        }
    }
}

/// A tenant's shared queue end: the receiver plus the disconnect flag any
/// worker's poll may set (so every sibling learns the producer finished).
struct TenantQueue {
    rx: mpsc::Receiver<Request>,
    closed: bool,
}

/// Non-blocking poll of a tenant queue (workers never block on one
/// tenant — that would stall every other tenant's service).
fn poll(q: &Mutex<TenantQueue>) -> Option<Request> {
    let mut g = relock(q);
    match g.rx.try_recv() {
        Ok(r) => Some(r),
        Err(mpsc::TryRecvError::Empty) => None,
        Err(mpsc::TryRecvError::Disconnected) => {
            g.closed = true;
            None
        }
    }
}

/// Serve a mix of tenants open-loop over one shared worker pool. All
/// models compile through one [`DiscCompiler`] (shared device, kernel
/// store, weight store — the cross-tenant sharing this engine arbitrates);
/// each worker thread owns one forked executor per tenant and runs
/// deficit round-robin across the tenant queues. Returns per-tenant
/// reports (spec order) after reconciling the zero-lost invariant for
/// every tenant.
pub fn serve_mix(specs: Vec<TenantSpec>, opts: &MixOptions) -> Result<MixReport> {
    anyhow::ensure!(!specs.is_empty(), "serve_mix needs at least one tenant");
    let workers = opts.workers.max(1);
    let faults = opts.faults.clone().or_else(FaultPlan::from_env);
    let compiler = DiscCompiler::with_faults(faults.clone())?;

    // Compile every tenant's model through the one compiler, register its
    // residency floor, and deal one forked executor per tenant to each
    // worker.
    let mut progs: Vec<Arc<Program>> = Vec::with_capacity(specs.len());
    let mut modules: Vec<Module> = Vec::with_capacity(specs.len());
    let mut models = Vec::with_capacity(specs.len());
    let mut worker_execs: Vec<Vec<Executor>> = (0..workers).map(|_| Vec::new()).collect();
    for spec in &specs {
        let w = workloads::by_name(&spec.workload).ok_or_else(|| {
            anyhow::anyhow!("tenant {}: unknown workload '{}'", spec.name, spec.workload)
        })?;
        let m = crate::bridge::lower(&w.graph)
            .with_context(|| format!("tenant {}: lowering", spec.name))?;
        let model = compiler
            .compile(m, &CompileOptions::mode(Mode::Disc))
            .with_context(|| format!("tenant {}: compile", spec.name))?;
        if spec.weight_floor_bytes > 0 {
            if let Some(pid) = model.program_id() {
                compiler.weight_store().set_floor(pid, spec.weight_floor_bytes);
            }
        }
        modules.push(model.module().clone());
        let (prog, execs) = model.fork_workers(workers)?;
        progs.push(prog);
        for (wi, e) in execs.into_iter().enumerate() {
            worker_execs[wi].push(e);
        }
        // Kept alive for the per-tenant re-bucketing loops and the final
        // policy-gauge fold (each tenant has its own PolicySwitch).
        models.push(model);
    }
    if let Some(budget) = opts.weight_budget_bytes {
        compiler.weight_store().set_max_bytes(budget);
    }

    // One bounded queue + one open-loop producer per tenant (the admission
    // bulkhead): a flood fills and sheds from its own queue only.
    let mut producers = Vec::with_capacity(specs.len());
    let mut queue_vec = Vec::with_capacity(specs.len());
    for spec in &specs {
        let w = workloads::by_name(&spec.workload).expect("validated above");
        let stream = w.request_stream(spec.requests, spec.seed);
        let (tx, rx) = mpsc::sync_channel::<Request>(spec.queue_cap.max(1));
        producers.push(spawn_producer(tx, stream, spec.rate_rps, spec.arrival, spec.deadline));
        queue_vec.push(Mutex::new(TenantQueue { rx, closed: false }));
    }
    let queues = Arc::new(queue_vec);
    let breakers: Arc<Vec<Mutex<Breaker>>> = Arc::new(
        specs
            .iter()
            .map(|_| Mutex::new(Breaker::new(opts.breaker_threshold, opts.probe_after)))
            .collect(),
    );
    let specs = Arc::new(specs);
    let modules = Arc::new(modules);
    // One background re-bucketing loop per tenant: each periodically
    // re-derives boundaries from its own traffic histogram, pre-compiles
    // the candidate family through the shared store, and hot-swaps its
    // tenant's policy epoch — off every worker's hot path.
    let rebucketers: Vec<super::Rebucketer> =
        match opts.rebucket_interval.filter(|iv| !iv.is_zero()) {
            Some(iv) => models
                .iter()
                .filter_map(|m| super::spawn_rebucketer(m, iv, opts.max_buckets))
                .collect(),
            None => Vec::new(),
        };
    let start = Instant::now();

    type WorkerOut = (Vec<Vec<Completion>>, Vec<RunMetrics>, Vec<usize>);
    let handles: Vec<_> = worker_execs
        .into_iter()
        .enumerate()
        .map(|(wi, mut execs)| {
            let specs = specs.clone();
            let progs = progs.clone();
            let modules = modules.clone();
            let queues = queues.clone();
            let breakers = breakers.clone();
            let faults = faults.clone();
            let opts = opts.clone();
            std::thread::Builder::new()
                .name(format!("disc-mix-{wi}"))
                .spawn(move || -> Result<WorkerOut> {
                    let t_count = execs.len();
                    let analyses: Vec<_> = execs
                        .iter_mut()
                        .zip(progs.iter())
                        .map(|(e, p)| (opts.max_batch > 1).then(|| e.batch_analysis(p)))
                        .collect();
                    let mut completions_v: Vec<Vec<Completion>> =
                        (0..t_count).map(|_| Vec::new()).collect();
                    let mut metrics_v: Vec<RunMetrics> = vec![RunMetrics::default(); t_count];
                    let mut launches_v: Vec<usize> = vec![0; t_count];
                    let mut pendings: Vec<VecDeque<Stashed>> =
                        (0..t_count).map(|_| VecDeque::new()).collect();
                    let mut deficits: Vec<i64> = vec![0; t_count];
                    loop {
                        let mut did_work = false;
                        for t in 0..t_count {
                            // Deficit round-robin: top up by the tenant's
                            // weight, spend one per request served. The cap
                            // keeps an idle tenant's unused share from
                            // accumulating into a later burst.
                            let quantum = specs[t].weight.max(1) as i64;
                            deficits[t] = (deficits[t] + quantum).min(quantum * 16);
                            while deficits[t] > 0 {
                                let mut key_of = |req: &Request| {
                                    analyses[t].as_ref().and_then(|a| {
                                        group_key_extent(&progs[t].module, a, &req.inputs)
                                    })
                                };
                                let (head, head_tag) = match pendings[t].pop_front() {
                                    Some(s) => (s.req, s.tag),
                                    None => match poll(&queues[t]) {
                                        Some(r) => {
                                            let k = key_of(&r);
                                            (r, k)
                                        }
                                        None => {
                                            // Out of work: a deficit only
                                            // carries over while backlogged.
                                            deficits[t] = 0;
                                            break;
                                        }
                                    },
                                };
                                did_work = true;
                                let mut next = || poll(&queues[t]);
                                let (batch, _shape) = assemble_batch(
                                    head,
                                    head_tag,
                                    &mut pendings[t],
                                    opts.max_batch,
                                    specs[t].slo.batch_window(),
                                    None,
                                    &mut key_of,
                                    &mut next,
                                );
                                deficits[t] -= batch.len() as i64;
                                // Deadline admission control, per tenant.
                                let now = Instant::now();
                                let mut expired = 0u64;
                                let batch: Vec<Request> = batch
                                    .into_iter()
                                    .filter(|r| match r.deadline {
                                        Some(d) if now >= d => {
                                            expired += 1;
                                            false
                                        }
                                        _ => true,
                                    })
                                    .collect();
                                metrics_v[t].deadline_misses += expired;
                                if batch.is_empty() {
                                    continue;
                                }
                                let gate = relock(&breakers[t]).admit();
                                match gate {
                                    Gate::Quarantine => match opts.quarantine {
                                        Quarantine::Shed => {
                                            metrics_v[t].quarantined += batch.len() as u64;
                                            metrics_v[t].shed_requests += batch.len() as u64;
                                        }
                                        Quarantine::Reference => {
                                            // Bottom rung of the ladder:
                                            // host reference answers, one
                                            // member at a time.
                                            for r in batch {
                                                let delay = r.arrived.elapsed();
                                                let t0 = Instant::now();
                                                let out =
                                                    reference::eval_module(&modules[t], &r.inputs)
                                                        .with_context(|| {
                                                            format!(
                                                                "tenant {}: quarantine reference",
                                                                specs[t].name
                                                            )
                                                        })?;
                                                metrics_v[t].quarantined += 1;
                                                metrics_v[t].demotions += 1;
                                                launches_v[t] += 1;
                                                completions_v[t].push(Completion {
                                                    id: r.id,
                                                    latency: delay + t0.elapsed(),
                                                    queue_delay: delay,
                                                    outputs: if opts.capture_outputs {
                                                        Some(out.outputs)
                                                    } else {
                                                        None
                                                    },
                                                });
                                            }
                                        }
                                    },
                                    Gate::Real | Gate::Probe => {
                                        let probe = gate == Gate::Probe;
                                        let delays: Vec<Duration> =
                                            batch.iter().map(|r| r.arrived.elapsed()).collect();
                                        let metas: Vec<_> = batch
                                            .iter()
                                            .map(|r| (r.id, r.arrived, r.deadline, r.requeues))
                                            .collect();
                                        let inputs: Vec<Vec<Tensor>> =
                                            batch.into_iter().map(|r| r.inputs).collect();
                                        let t0 = Instant::now();
                                        let r = catch_unwind(AssertUnwindSafe(|| {
                                            // Panic faults attribute to the
                                            // fault-target tenant only.
                                            if let Some(f) =
                                                faults.as_ref().filter(|_| specs[t].fault_target)
                                            {
                                                if f.should_fail(FaultSite::WorkerPanic) {
                                                    panic!(
                                                        "injected panic fault (tenant {} dispatch)",
                                                        specs[t].name
                                                    );
                                                }
                                            }
                                            execs[t].run_batch(&progs[t], &inputs).with_context(
                                                || {
                                                    format!(
                                                        "tenant {} worker {wi}",
                                                        specs[t].name
                                                    )
                                                },
                                            )
                                        }));
                                        match r {
                                            Ok(Ok(out)) => {
                                                relock(&breakers[t]).on_success(probe);
                                                let dt = t0.elapsed();
                                                launches_v[t] += 1;
                                                metrics_v[t] += &out.metrics;
                                                let mut outs = out.outputs.into_iter();
                                                for (j, (id, ..)) in
                                                    metas.into_iter().enumerate()
                                                {
                                                    let produced = outs.next();
                                                    completions_v[t].push(Completion {
                                                        id,
                                                        latency: delays[j] + dt,
                                                        queue_delay: delays[j],
                                                        outputs: if opts.capture_outputs {
                                                            produced
                                                        } else {
                                                            None
                                                        },
                                                    });
                                                }
                                            }
                                            Ok(Err(e)) => {
                                                relock(&breakers[t]).on_failure(probe);
                                                return Err(e);
                                            }
                                            Err(_panicked) => {
                                                // Supervision: count the
                                                // restart against THIS
                                                // tenant, swap in a fresh
                                                // executor, requeue the
                                                // in-flight batch.
                                                relock(&breakers[t]).on_failure(probe);
                                                metrics_v[t].worker_restarts += 1;
                                                let fresh = execs[t].fork();
                                                execs[t] = fresh;
                                                for ((id, arrived, deadline, requeues), ins) in
                                                    metas.into_iter().zip(inputs)
                                                {
                                                    if requeues >= opts.max_requeues {
                                                        metrics_v[t].shed_requests += 1;
                                                        continue;
                                                    }
                                                    let req = Request {
                                                        id,
                                                        inputs: ins,
                                                        arrived,
                                                        deadline,
                                                        requeues: requeues + 1,
                                                    };
                                                    let tag = key_of(&req);
                                                    pendings[t]
                                                        .push_back(Stashed { req, tag });
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        let all_done = queues
                            .iter()
                            .enumerate()
                            .all(|(t, q)| relock(q).closed && pendings[t].is_empty());
                        if all_done {
                            break;
                        }
                        if !did_work {
                            std::thread::sleep(Duration::from_micros(100));
                        }
                    }
                    Ok((completions_v, metrics_v, launches_v))
                })
                .expect("spawning mix worker thread")
        })
        .collect();

    let t_count = specs.len();
    let mut completions_all: Vec<Vec<Completion>> = (0..t_count).map(|_| Vec::new()).collect();
    let mut metrics_all: Vec<RunMetrics> = vec![RunMetrics::default(); t_count];
    let mut launches_all: Vec<usize> = vec![0; t_count];
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok((comps, mets, lns))) => {
                for (t, c) in comps.into_iter().enumerate() {
                    completions_all[t].extend(c);
                }
                for (t, m) in mets.iter().enumerate() {
                    metrics_all[t] += m;
                }
                for (t, l) in lns.into_iter().enumerate() {
                    launches_all[t] += l;
                }
            }
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err = first_err
                    .or_else(|| Some(anyhow::anyhow!("mix worker panicked outside dispatch")));
            }
        }
    }
    // Producers never block (try_send sheds on a full queue), so they run
    // their streams to completion regardless of worker health — join them
    // to fold their shed counts into the per-tenant accounting.
    let producer_shed: Vec<u64> = producers.into_iter().map(|p| p.join().unwrap_or(0)).collect();
    for r in rebucketers {
        r.stop();
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall = start.elapsed();

    let mut tenants = Vec::with_capacity(t_count);
    let mut aggregate = RunMetrics::default();
    for (t, spec) in specs.iter().enumerate() {
        let mut metrics = std::mem::take(&mut metrics_all[t]);
        metrics.shed_requests += producer_shed[t];
        let (trips, probes) = {
            let b = relock(&breakers[t]);
            (b.trips, b.probes)
        };
        metrics.breaker_trips += trips;
        super::fold_policy_metrics(&models[t], &mut metrics);
        let completions = std::mem::take(&mut completions_all[t]);
        // The zero-lost invariant, PER TENANT: nothing this tenant offered
        // is unaccounted, no matter what its neighbors (or its own fault
        // storm) did.
        reconcile(&completions, &metrics, spec.requests)
            .with_context(|| format!("tenant {}", spec.name))?;
        aggregate += &metrics;
        tenants.push(TenantReport {
            name: spec.name.clone(),
            slo: spec.slo,
            offered: spec.requests,
            breaker_trips: trips,
            probes,
            report: ServeReport::from_completions(
                completions,
                wall,
                metrics,
                Vec::new(),
                launches_all[t],
            ),
        });
    }
    Ok(MixReport { wall, tenants, aggregate })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_trips_quarantines_probes_and_readmits() {
        let mut b = Breaker::new(2, 3);
        assert_eq!(b.admit(), Gate::Real);
        b.on_failure(false);
        assert_eq!(b.admit(), Gate::Real, "one failure is below the threshold");
        b.on_failure(false);
        assert_eq!(b.trips, 1, "second consecutive failure trips");
        // Open: quarantine until the probe clock expires.
        assert_eq!(b.admit(), Gate::Quarantine);
        assert_eq!(b.admit(), Gate::Quarantine);
        assert_eq!(b.admit(), Gate::Probe, "third observed dispatch probes");
        assert_eq!(b.probes, 1);
        // While the probe is in flight, siblings keep quarantining.
        assert_eq!(b.admit(), Gate::Quarantine);
        // Failed probe: back to open, clock restarted.
        b.on_failure(true);
        assert_eq!(b.trips, 1, "a failed probe re-opens without a new trip");
        assert_eq!(b.admit(), Gate::Quarantine);
        assert_eq!(b.admit(), Gate::Quarantine);
        assert_eq!(b.admit(), Gate::Probe);
        // Successful probe: closed, service restored.
        b.on_success(true);
        assert_eq!(b.admit(), Gate::Real);
        // An intervening success resets the consecutive count.
        b.on_failure(false);
        b.on_success(false);
        b.on_failure(false);
        assert_eq!(b.admit(), Gate::Real, "non-consecutive failures never trip");
        assert_eq!(b.trips, 1);
    }

    #[test]
    fn mix_serves_every_tenant_and_reconciles_per_tenant() {
        let specs = vec![
            TenantSpec::latency("lat", "transformer").requests(16).rate(400.0).seed(11),
            TenantSpec::throughput("thr", "tts").requests(24).rate(800.0).seed(12),
        ];
        let opts = MixOptions::new().workers(2).batch(3).keep_outputs();
        let report = serve_mix(specs, &opts).unwrap();
        assert_eq!(report.tenants.len(), 2);
        for t in &report.tenants {
            // serve_mix already reconciled; spot-check the balance here
            // so a regression fails loudly in this test too.
            let m = &t.report.metrics;
            assert_eq!(
                t.report.completed as u64 + m.shed_requests + m.deadline_misses,
                t.offered as u64,
                "tenant {} lost requests",
                t.name
            );
            assert_eq!(
                t.report.outputs.len(),
                t.report.completed,
                "tenant {} must capture one output set per completion",
                t.name
            );
            assert_eq!(m.breaker_trips, 0, "fault-free mix must not trip breakers");
            assert_eq!(m.quarantined, 0);
        }
        assert!(report.tenants[0].report.completed > 0);
        assert!(report.tenants[1].report.completed > 0);
    }

    #[test]
    fn rebucketing_mix_stays_reconciled_and_reports_per_tenant_gauges() {
        let specs = vec![
            TenantSpec::latency("lat", "transformer").requests(12).rate(600.0).seed(31),
            TenantSpec::throughput("thr", "tts").requests(18).rate(900.0).seed(32),
        ];
        let opts =
            MixOptions::new().workers(2).batch(3).rebucket_every_ms(1).max_buckets(4);
        let report = serve_mix(specs, &opts).unwrap();
        for t in &report.tenants {
            let m = &t.report.metrics;
            assert_eq!(
                t.report.completed as u64 + m.shed_requests + m.deadline_misses,
                t.offered as u64,
                "tenant {} lost requests under re-bucketing",
                t.name
            );
            // Every tenant's dispatches feed its own histogram, so each
            // report carries a non-empty per-symbol snapshot.
            assert!(
                !m.extent_hist.is_empty(),
                "tenant {} must snapshot its extent histogram",
                t.name
            );
        }
        // Option composition.
        let off = MixOptions::new().rebucket_every_ms(0);
        assert!(off.rebucket_interval.is_none());
        assert_eq!(MixOptions::new().max_buckets(0).max_buckets, 1);
    }

    #[test]
    fn fault_storm_trips_only_the_target_tenant() {
        // Every real dispatch of the faulty tenant panics until the cap
        // (4 fires) is spent; threshold 2 trips the breaker, quarantine
        // serves the rest via the reference evaluator, and a later probe
        // re-admits. The healthy tenant must never notice.
        let plan = Arc::new(FaultPlan::parse("seed=5,panic=1000:4").unwrap());
        let specs = vec![
            TenantSpec::latency("healthy", "tts").requests(20).rate(500.0).seed(21),
            TenantSpec::throughput("faulty", "tts")
                .requests(30)
                .rate(900.0)
                .seed(22)
                .fault_target(),
        ];
        let opts = MixOptions::new().workers(2).batch(2).faults(plan.clone()).breaker(2, 2);
        let report = serve_mix(specs, &opts).unwrap();
        let healthy = &report.tenants[0];
        let faulty = &report.tenants[1];
        assert!(faulty.breaker_trips >= 1, "the storm must trip the faulty breaker");
        assert!(
            faulty.report.metrics.quarantined > 0,
            "open-breaker dispatches must be quarantined"
        );
        assert_eq!(
            faulty.report.metrics.worker_restarts,
            plan.fired(FaultSite::WorkerPanic),
            "every injected panic is one supervised restart, attributed to the target"
        );
        // Bulkhead: the healthy tenant saw full replay-tier service.
        let hm = &healthy.report.metrics;
        assert_eq!(healthy.report.completed, healthy.offered);
        assert_eq!(hm.shed_requests, 0, "healthy tenant must shed nothing");
        assert_eq!(hm.demotions, 0, "healthy tenant must never demote");
        assert_eq!(hm.worker_restarts, 0);
        assert_eq!(hm.quarantined, 0);
        assert_eq!(healthy.breaker_trips, 0);
    }
}
