//! The DISC compiler driver: frontend module → optimized DHLO → fusion plan
//! → generated runtime flow → executable model, under one of the execution
//! modes the paper evaluates against.

use crate::codegen::{BucketPolicy, KernelStore};
use crate::dhlo::Module;
use crate::fusion::{self, FusionOptions, FusionPlan};
use crate::library::WeightStore;
use crate::passes;
use crate::passes::static_detect::{analyze, PipelineChoice};
use crate::program::{generate, Program};
use crate::runtime::batching::{BatchAnalysis, BatchOutput};
use crate::runtime::eager::Eager;
use crate::runtime::executor::{DecodeOutput, ExecOptions, ExecOutput, Executor, RuntimeOptions};
use crate::runtime::kv::DecodeSpec;
use crate::runtime::pjrt::Device;
use crate::runtime::tensor::Tensor;
use crate::vm::Vm;
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// Execution modes (the systems compared in the paper's evaluation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Framework-eager: one kernel launch per op, vendor-library GEMMs
    /// (the TensorFlow/PyTorch baseline of Fig. 3).
    Eager,
    /// Nimble-like VM: interpreted runtime flow, propagation-only fusion
    /// (the §5.2 comparator).
    VmNimble,
    /// DISC: constraint-driven fusion, compile-time-generated runtime flow,
    /// bucketed shape-agnostic kernel cache.
    Disc,
    /// XLA-like static pipeline: exact-shape kernels, recompiled per new
    /// shape (the §2 motivation; also the Fig. 4 static-optimization bar
    /// when the input graph itself is static).
    Static,
    /// DISC with automatic static fallback (§4.4): fully-static graphs
    /// take the static pipeline.
    Auto,
}

/// Compilation options.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    pub mode: Mode,
    /// Overrides for ablations; `None` picks the mode's defaults.
    pub fusion: Option<FusionOptions>,
    pub policy: Option<BucketPolicy>,
    /// Run the optimization pass pipeline (fold/cse/dce) before planning.
    pub optimize: bool,
    pub pooled_buffers: bool,
    /// Cache + replay resolved launch plans per symbol binding (tier 3 of
    /// the runtime pipeline; see docs/runtime.md).
    pub plan_cache: bool,
    /// Keep fused/GEMM results device-resident during plan replays.
    pub device_resident: bool,
    /// Runtime feature toggles shared verbatim with the executor (weight
    /// cache, speculative warming, symbolic memory planning); see
    /// [`RuntimeOptions`].
    pub runtime: RuntimeOptions,
}

impl CompileOptions {
    pub fn mode(mode: Mode) -> Self {
        CompileOptions {
            mode,
            fusion: None,
            policy: None,
            optimize: true,
            pooled_buffers: true,
            plan_cache: true,
            device_resident: true,
            runtime: RuntimeOptions::default(),
        }
    }
}

/// Compile-time report.
#[derive(Debug, Clone)]
pub struct CompileReport {
    pub mode: Mode,
    /// Pipeline actually chosen (differs from `mode` under `Auto`).
    pub pipeline: &'static str,
    pub compile_time: Duration,
    pub instrs_before: usize,
    pub instrs_after: usize,
    pub fusion_groups: usize,
    pub planned_kernels: usize,
    pub static_fraction: f64,
}

enum Backend {
    Eager { eager: Eager, module: Module },
    Vm { vm: Vm, module: Module, plan: FusionPlan },
    Program { exec: Executor, prog: Arc<Program> },
}

/// A compiled model: run requests against it; caches persist across runs.
pub struct CompiledModel {
    backend: Backend,
    pub report: CompileReport,
}

impl CompiledModel {
    pub fn run(&mut self, inputs: &[Tensor]) -> Result<ExecOutput> {
        match &mut self.backend {
            Backend::Eager { eager, module } => eager.run(module, inputs),
            Backend::Vm { vm, module, plan } => vm.run(module, plan, inputs),
            Backend::Program { exec, prog } => exec.run(prog, inputs),
        }
    }

    /// Execute several requests as one batched dispatch (program backends;
    /// see `runtime::batching`). Outputs are per request, bit-identical to
    /// solo runs. Baseline backends — and batches the program cannot
    /// stack — fall back to sequential solo execution.
    pub fn run_batch(&mut self, requests: &[Vec<Tensor>]) -> Result<BatchOutput> {
        if let Backend::Program { exec, prog } = &mut self.backend {
            return exec.run_batch(prog, requests);
        }
        let mut outputs = Vec::with_capacity(requests.len());
        let mut metrics = crate::runtime::metrics::RunMetrics::default();
        for r in requests {
            let out = self.run(r)?;
            metrics += &out.metrics;
            outputs.push(out.outputs);
        }
        Ok(BatchOutput { outputs, metrics })
    }

    /// Drive one request's whole autoregressive decode loop (see
    /// `Executor::run_decode`): per-request KV slab in the arena's KV
    /// residency class, one plan family replayed per bucket. Program
    /// backends only — decode serving is a runtime-flow feature.
    pub fn run_decode(
        &mut self,
        spec: &DecodeSpec,
        prompt: &[i64],
        gen_steps: usize,
    ) -> Result<DecodeOutput> {
        match &mut self.backend {
            Backend::Program { exec, prog } => exec.run_decode(prog, spec, prompt, gen_steps),
            _ => anyhow::bail!(
                "decode serving requires a program backend (disc/static/auto mode)"
            ),
        }
    }

    /// Acquire a KV-slab lease in the executor arena's KV residency class —
    /// the seam the decode scheduler accounts per-request slabs through
    /// (and where an injected OOM surfaces). Dropping the returned lease
    /// releases the slab (request exit or bucket rollover). Baselines hold
    /// no arena and accept silently with `Ok(None)`.
    pub fn kv_acquire(
        &mut self,
        bytes: u64,
    ) -> Result<Option<crate::runtime::buffers::ArenaLease>> {
        if let Backend::Program { exec, .. } = &mut self.backend {
            let faults = exec.device.faults().cloned();
            let lease = exec.pool.device.acquire(
                crate::runtime::buffers::ResidencyClass::Kv,
                bytes,
                faults.as_deref(),
            )?;
            return Ok(Some(lease));
        }
        Ok(None)
    }

    /// Current and peak KV-slab residency of the backend arena.
    pub fn kv_residency(&self) -> (u64, u64) {
        match &self.backend {
            Backend::Program { exec, .. } => {
                (exec.pool.device.kv_resident_bytes(), exec.pool.device.kv_high_water_bytes())
            }
            _ => (0, 0),
        }
    }

    /// The bucket policy decode KV slabs grow by (must match the executor
    /// so every step binds at the slab's padded capacity). Baselines fall
    /// back to the eager default.
    pub fn bucket_policy(&self) -> BucketPolicy {
        match &self.backend {
            Backend::Vm { vm, .. } => vm.cache.policy(),
            Backend::Program { exec, .. } => exec.opts.policy,
            Backend::Eager { .. } => BucketPolicy::NextPow2,
        }
    }

    /// The live traffic-adaptive policy handle (histogram + epoch + current
    /// [`Boundaries`](crate::codegen::Boundaries)) — shared with every
    /// forked worker. `None` for baseline backends, whose policy is fixed
    /// at compile time.
    pub fn policy_switch(&self) -> Option<Arc<crate::codegen::PolicySwitch>> {
        match &self.backend {
            Backend::Program { exec, .. } => Some(exec.switch.clone()),
            _ => None,
        }
    }

    /// Re-derive bucket boundaries from the traffic observed so far,
    /// pre-compile the new bucket family, and hot-swap the policy epoch
    /// (see `Executor::rebucket`). Returns `Ok(true)` when a new policy
    /// was installed, `Ok(false)` when traffic was empty or the derived
    /// cuts matched the live ones. Program backends only; baselines are a
    /// no-op `Ok(false)`. The serving coordinator calls this from its
    /// background re-bucketing loop; benches call it directly for a
    /// deterministic flip.
    pub fn rebucket_now(&mut self, max_cuts: usize) -> Result<bool> {
        match &mut self.backend {
            Backend::Program { exec, prog } => exec.rebucket(prog, max_cuts),
            _ => Ok(false),
        }
    }

    /// Shrink (or grow) the executor's launch/batch-plan FIFO capacity —
    /// tests lower it to watch stale-epoch plans retire. No-op for
    /// baseline backends.
    pub fn set_max_plans(&mut self, n: usize) {
        if let Backend::Program { exec, .. } = &mut self.backend {
            exec.max_plans = n;
        }
    }

    /// The program plus its (cached) batchability analysis, for batch
    /// assembly in the serving coordinator. `None` for baseline backends,
    /// which never batch.
    pub fn batch_context(&mut self) -> Option<(Arc<Program>, Arc<BatchAnalysis>)> {
        match &mut self.backend {
            Backend::Program { exec, prog } => Some((prog.clone(), exec.batch_analysis(prog))),
            _ => None,
        }
    }

    /// The compiled program's process-unique id — the key the shared
    /// `WeightStore` arbitrates per-tenant residency floors by. `None` for
    /// baseline backends (they hold no program and cache no weights).
    pub fn program_id(&self) -> Option<u64> {
        match &self.backend {
            Backend::Program { prog, .. } => Some(prog.id),
            _ => None,
        }
    }

    /// The module the backend executes (post-optimization).
    pub fn module(&self) -> &Module {
        match &self.backend {
            Backend::Eager { module, .. } => module,
            Backend::Vm { module, .. } => module,
            Backend::Program { exec: _, prog } => &prog.module,
        }
    }

    /// Kernel-cache stats (compile events over the model's lifetime).
    pub fn cache_stats(&self) -> Option<crate::codegen::CacheStats> {
        match &self.backend {
            Backend::Eager { .. } => None,
            Backend::Vm { vm, .. } => Some(vm.cache.stats.clone()),
            Backend::Program { exec, .. } => Some(exec.cache.stats.clone()),
        }
    }

    /// Launch-plan cache stats (program backends only).
    pub fn plan_stats(&self) -> Option<crate::runtime::plan::PlanStats> {
        match &self.backend {
            Backend::Program { exec, .. } => Some(exec.plan_stats.clone()),
            _ => None,
        }
    }

    /// Batch plan-cache stats (program backends only): the record/replay
    /// behavior of whole batch groups (see `runtime::batching`).
    pub fn batch_plan_stats(&self) -> Option<crate::runtime::plan::PlanStats> {
        match &self.backend {
            Backend::Program { exec, .. } => Some(exec.batch_plan_stats.clone()),
            _ => None,
        }
    }

    /// Fork `n` sibling executor workers for multi-worker serving: each
    /// shares the process-wide kernel store, weight store, and device with
    /// this model (compile-once / upload-once across all of them) while
    /// owning its own plan cache and buffer pools. Program backends only —
    /// the eager/VM baselines model the paper's single-stream deployment.
    pub fn fork_workers(&self, n: usize) -> Result<(Arc<Program>, Vec<Executor>)> {
        match &self.backend {
            Backend::Program { exec, prog } => {
                Ok((prog.clone(), (0..n).map(|_| exec.fork()).collect()))
            }
            _ => anyhow::bail!(
                "multi-worker serving requires a program backend (disc/static/auto mode)"
            ),
        }
    }

    /// Replace a program backend's executor with a freshly forked sibling
    /// (shared stores, fresh per-worker caches) after a panic unwound
    /// through a dispatch and left its state suspect. Baseline backends
    /// keep their engine — they hold no launch state across requests.
    pub fn restart_worker(&mut self) {
        if let Backend::Program { exec, .. } = &mut self.backend {
            let fresh = exec.fork();
            *exec = fresh;
        }
    }
}

/// The compiler itself: owns the device handle **and the process-wide
/// stores** shared by every model (and every forked worker) it compiles —
/// the shard-locked [`KernelStore`] (each pattern×bucket compiles exactly
/// once per process, with misses served by the background compile pool)
/// and the [`WeightStore`] (each static GEMM weight uploads exactly once
/// per program). A serving process builds one `DiscCompiler` and threads
/// it everywhere.
pub struct DiscCompiler {
    pub device: Arc<Device>,
    store: Arc<KernelStore>,
    weights: Arc<WeightStore>,
}

impl DiscCompiler {
    pub fn new() -> Result<Self> {
        Ok(Self::with_device(Arc::new(Device::cpu()?)))
    }

    /// A compiler whose device injects from an explicit fault schedule
    /// (chaos tests; `new()` reads `DISC_FAULTS` via `Device::cpu`).
    pub fn with_faults(
        faults: Option<Arc<crate::runtime::faults::FaultPlan>>,
    ) -> Result<Self> {
        Ok(Self::with_device(Arc::new(Device::cpu_with_faults(faults)?)))
    }

    pub fn with_device(device: Arc<Device>) -> Self {
        let store = Arc::new(KernelStore::new(device.clone()));
        DiscCompiler { device, store, weights: Arc::new(WeightStore::new()) }
    }

    /// The process-wide kernel store (benches/tests inspect its snapshot
    /// for the compile-once-across-workers claim).
    pub fn kernel_store(&self) -> &Arc<KernelStore> {
        &self.store
    }

    /// The process-wide weight store.
    pub fn weight_store(&self) -> &Arc<WeightStore> {
        &self.weights
    }

    /// Compile a DHLO module under the given options.
    pub fn compile(&self, module: Module, opts: &CompileOptions) -> Result<CompiledModel> {
        let t0 = std::time::Instant::now();
        let instrs_before = module.instrs.len();
        let module = if opts.optimize { passes::optimize(&module)? } else { module };
        crate::dhlo::verify::verify(&module)?;
        let report_base = analyze(&module);

        // Resolve mode defaults.
        let (fusion_opts, policy, pipeline) = match opts.mode {
            Mode::Eager => (
                FusionOptions { enabled: false, ..Default::default() },
                BucketPolicy::NextPow2,
                "eager",
            ),
            // Nimble's TVM-based fusion: shape propagation only (no
            // constraint collection), no reduce-rooted input fusion, and a
            // TVM-like fuse-depth limit — "DISC pays more attention to
            // memory intensive fusion comparing with Nimble" (§6).
            Mode::VmNimble => (
                FusionOptions {
                    use_constraints: false,
                    enable_input_fusion: false,
                    max_group_size: 4,
                    enabled: true,
                },
                // Nimble tunes kernels "under a set of fixed shapes" and
                // reuses them for others (§4.5): modeled as coarse fixed
                // buckets, paying padding traffic on off-tune shapes.
                BucketPolicy::MultipleOf(64),
                "vm",
            ),
            // Fine-grained buckets: the paper's DISC adapts launch dims to
            // any shape at runtime; with AOT executables the analogue is a
            // dense bucket family (≤6% linear padding at multiple-of-16).
            Mode::Disc => (FusionOptions::default(), BucketPolicy::MultipleOf(16), "dynamic"),
            Mode::Static => (FusionOptions::default(), BucketPolicy::Exact, "static"),
            Mode::Auto => {
                if report_base.choice == PipelineChoice::Static {
                    (FusionOptions::default(), BucketPolicy::Exact, "static(auto)")
                } else {
                    (FusionOptions::default(), BucketPolicy::MultipleOf(16), "dynamic(auto)")
                }
            }
        };
        let fusion_opts = opts.fusion.clone().unwrap_or(fusion_opts);
        let policy = opts.policy.unwrap_or(policy);

        let plan = fusion::plan(&module, &fusion_opts);
        let fusion_groups = plan.groups.len();
        let planned_kernels = plan.kernel_count(&module);
        let instrs_after = module.instrs.len();

        let backend = match opts.mode {
            Mode::Eager => {
                Backend::Eager { eager: Eager::new(self.device.clone()), module }
            }
            Mode::VmNimble => {
                Backend::Vm { vm: Vm::new(self.device.clone(), policy), module, plan }
            }
            _ => {
                let prog = Arc::new(generate(module, &plan)?);
                let mut exec = Executor::with_shared(
                    self.device.clone(),
                    ExecOptions {
                        policy,
                        pooled_buffers: opts.pooled_buffers,
                        plan_cache: opts.plan_cache,
                        device_resident: opts.device_resident,
                        runtime: opts.runtime.clone(),
                    },
                    self.store.clone(),
                    self.weights.clone(),
                );
                // The batchability analysis is pure compile-time shape
                // reasoning: compute it once here and store it with the
                // model, so serving (this executor and every forked
                // worker) never re-derives the classification.
                exec.seed_batch_analysis(
                    prog.id,
                    Arc::new(crate::runtime::batching::analyze(&prog)),
                );
                // So is the symbolic memory plan: live intervals and slot
                // coloring depend only on the program and bucket policy, so
                // it is built once at compile time and instantiated per
                // binding when plans install.
                exec.seed_memory_plan(
                    prog.id,
                    Arc::new(crate::runtime::memplan::MemoryPlan::build(&prog, policy)),
                );
                Backend::Program { exec, prog }
            }
        };

        Ok(CompiledModel {
            backend,
            report: CompileReport {
                mode: opts.mode,
                pipeline,
                compile_time: t0.elapsed(),
                instrs_before,
                instrs_after,
                fusion_groups,
                planned_kernels,
                static_fraction: report_base.static_fraction,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::runtime::reference::eval_module;
    use crate::shape::Dim;
    use crate::util::prng::Prng;

    fn attention_ish_module() -> Module {
        // A small attention-flavoured block: scores -> softmax -> weighted
        // sum, with residual + layernorm. Exercises dot, reduce, broadcast.
        let mut b = Builder::new("attn");
        let s = b.dyn_dim("seq", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(16)]);
        let wq = b.param(DType::F32, vec![Dim::Fixed(16), Dim::Fixed(16)]);
        let wk = b.param(DType::F32, vec![Dim::Fixed(16), Dim::Fixed(16)]);
        let g = b.param(DType::F32, vec![Dim::Fixed(16)]);
        let be = b.param(DType::F32, vec![Dim::Fixed(16)]);
        let q = b.dot(x, wq).unwrap();
        let k = b.dot(x, wk).unwrap();
        let kt = b.transpose(k, vec![1, 0]).unwrap();
        let scores = b.dot(q, kt).unwrap(); // [s, s]
        let scale = b.scalar_f32(0.25);
        let scaleb = b.broadcast_scalar_like(scale, scores).unwrap();
        let scaled = b.mul(scores, scaleb).unwrap();
        let attn = b.softmax_last(scaled).unwrap();
        let ctx = b.dot(attn, x).unwrap(); // [s, 16]
        let res = b.add(ctx, x).unwrap();
        let out = b.layernorm_last(res, g, be, 1e-5).unwrap();
        b.finish(vec![out])
    }

    fn inputs_for(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
        vec![
            Tensor::f32(&[seq, 16], rng.fill_f32(seq * 16, 1.0)),
            Tensor::f32(&[16, 16], rng.fill_f32(256, 0.3)),
            Tensor::f32(&[16, 16], rng.fill_f32(256, 0.3)),
            Tensor::f32(&[16], rng.fill_f32(16, 0.5)),
            Tensor::f32(&[16], rng.fill_f32(16, 0.5)),
        ]
    }

    #[test]
    fn all_modes_agree_on_attention_block() {
        let compiler = DiscCompiler::new().unwrap();
        let mut rng = Prng::new(11);
        let modes = [Mode::Eager, Mode::VmNimble, Mode::Disc, Mode::Static];
        let mut models: Vec<CompiledModel> = modes
            .iter()
            .map(|&mode| {
                compiler.compile(attention_ish_module(), &CompileOptions::mode(mode)).unwrap()
            })
            .collect();
        for seq in [3usize, 8, 13] {
            let inputs = inputs_for(seq, &mut rng);
            let want = eval_module(models[0].module(), &inputs).unwrap();
            for (mi, model) in models.iter_mut().enumerate() {
                let got = model.run(&inputs).unwrap();
                assert!(
                    got.outputs[0].allclose(&want.outputs[0], 2e-4, 2e-4).unwrap(),
                    "mode {:?} disagrees at seq {seq} (max diff {})",
                    modes[mi],
                    got.outputs[0].max_abs_diff(&want.outputs[0]).unwrap_or(f32::NAN),
                );
            }
        }
    }

    #[test]
    fn disc_launches_fewer_kernels_than_eager() {
        let compiler = DiscCompiler::new().unwrap();
        let mut rng = Prng::new(5);
        let mut disc =
            compiler.compile(attention_ish_module(), &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut eager =
            compiler.compile(attention_ish_module(), &CompileOptions::mode(Mode::Eager)).unwrap();
        let inputs = inputs_for(9, &mut rng);
        let d = disc.run(&inputs).unwrap();
        let e = eager.run(&inputs).unwrap();
        assert!(
            d.metrics.mem_kernels * 2 <= e.metrics.mem_kernels,
            "fusion should at least halve launches: disc {} vs eager {}",
            d.metrics.mem_kernels,
            e.metrics.mem_kernels
        );
        assert!(d.metrics.mem_bytes < e.metrics.mem_bytes);
        assert_eq!(d.metrics.lib_calls, e.metrics.lib_calls, "GEMMs identical");
    }

    #[test]
    fn static_mode_recompiles_per_shape_disc_does_not() {
        let compiler = DiscCompiler::new().unwrap();
        let mut rng = Prng::new(5);
        let mut disc =
            compiler.compile(attention_ish_module(), &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut stat =
            compiler.compile(attention_ish_module(), &CompileOptions::mode(Mode::Static)).unwrap();
        // Warm both with a stream of close-by shapes inside one bucket.
        for seq in [17usize, 18, 19, 20] {
            let inputs = inputs_for(seq, &mut rng);
            disc.run(&inputs).unwrap();
            stat.run(&inputs).unwrap();
        }
        let dstats = disc.cache_stats().unwrap();
        let sstats = stat.cache_stats().unwrap();
        assert!(
            dstats.misses < sstats.misses,
            "disc compiles per bucket ({}), static per shape ({})",
            dstats.misses,
            sstats.misses
        );
        assert!(dstats.hits > 0);
        assert_eq!(sstats.hits, 0);
    }

    #[test]
    fn auto_mode_falls_back_to_static() {
        let compiler = DiscCompiler::new().unwrap();
        // Fully static graph.
        let mut b = Builder::new("static");
        let x = b.param(DType::F32, vec![Dim::Fixed(8)]);
        let y = b.unary(UnKind::Tanh, x);
        let m = b.finish(vec![y]);
        let model = compiler.compile(m, &CompileOptions::mode(Mode::Auto)).unwrap();
        assert_eq!(model.report.pipeline, "static(auto)");
        // Dynamic graph keeps the dynamic pipeline.
        let model2 = compiler
            .compile(attention_ish_module(), &CompileOptions::mode(Mode::Auto))
            .unwrap();
        assert_eq!(model2.report.pipeline, "dynamic(auto)");
    }
}
