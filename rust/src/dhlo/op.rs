//! The DHLO op set and its classification tables.

use super::types::{DType, Literal};

/// Elementwise unary kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnKind {
    Abs,
    Neg,
    Exp,
    Log,
    Tanh,
    Sqrt,
    Rsqrt,
    Sigmoid,
    Relu,
    Gelu,
    Erf,
    Floor,
    Sign,
}

impl UnKind {
    pub fn name(&self) -> &'static str {
        match self {
            UnKind::Abs => "abs",
            UnKind::Neg => "negate",
            UnKind::Exp => "exponential",
            UnKind::Log => "log",
            UnKind::Tanh => "tanh",
            UnKind::Sqrt => "sqrt",
            UnKind::Rsqrt => "rsqrt",
            UnKind::Sigmoid => "logistic",
            UnKind::Relu => "relu",   // composite; expanded in codegen
            UnKind::Gelu => "gelu",   // composite; expanded in codegen
            UnKind::Erf => "erf",     // composite; expanded in codegen
            UnKind::Floor => "floor",
            UnKind::Sign => "sign",
        }
    }
}

/// Elementwise binary kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinKind {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
}

impl BinKind {
    pub fn name(&self) -> &'static str {
        match self {
            BinKind::Add => "add",
            BinKind::Sub => "subtract",
            BinKind::Mul => "multiply",
            BinKind::Div => "divide",
            BinKind::Max => "maximum",
            BinKind::Min => "minimum",
            BinKind::Pow => "power",
        }
    }
}

/// Comparison directions (result dtype is `pred`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpDir {
    pub fn hlo_direction(&self) -> &'static str {
        match self {
            CmpDir::Eq => "EQ",
            CmpDir::Ne => "NE",
            CmpDir::Lt => "LT",
            CmpDir::Le => "LE",
            CmpDir::Gt => "GT",
            CmpDir::Ge => "GE",
        }
    }
}

/// Reduction kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
    Min,
    Mean,
}

impl ReduceKind {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceKind::Sum => "sum",
            ReduceKind::Max => "max",
            ReduceKind::Min => "min",
            ReduceKind::Mean => "mean",
        }
    }
    /// Neutral element for masked (bucketed) reductions.
    pub fn neutral(&self) -> f32 {
        match self {
            ReduceKind::Sum | ReduceKind::Mean => 0.0,
            ReduceKind::Max => f32::NEG_INFINITY,
            ReduceKind::Min => f32::INFINITY,
        }
    }
}

/// Shape-propagation classes — the paper's table of propagation properties
/// (§4.3: "some ops may have the same shape propagation property, like Add
/// and Sub; we classify ops according to their shape propagation properties
/// in the table to avoid repeated enumeration").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PropClass {
    /// Output shape equals every (non-scalar) operand's shape.
    ElementwiseSameShape,
    /// Output holds exactly the operand's elements rearranged
    /// (Transpose/Reshape): tensor-size equality propagates.
    SizePreserving,
    /// Reduction roots: output covers the operand minus reduced axes;
    /// fusable as the root of an input fusion.
    Contracting,
    /// No useful propagation property (Slice, Pad, Concat, Gather, …).
    Opaque,
}

/// A DHLO operation. Static-attribute ops and their dynamic twins (figure 2
/// of the paper) coexist: `Slice` carries constant indices, `DSlice` reads
/// them from tensor operands at runtime.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Entry parameter `index`.
    Param { index: usize },
    /// Constant tensor with static dims.
    Const { lit: Literal, dims: Vec<usize> },
    Un(UnKind),
    Bin(BinKind),
    Cmp(CmpDir),
    /// `select(pred, on_true, on_false)`.
    Select,
    /// Elementwise dtype conversion.
    Convert(DType),
    /// `broadcast_in_dim`: `dims[i]` is the output axis operand axis `i`
    /// maps to. Output shape is fixed at construction time.
    Broadcast { dims: Vec<usize> },
    /// Dynamic broadcast: output extents come from an i64 shape-tensor
    /// operand (DHLO supplement of `broadcast_in_dim`).
    DBroadcast { dims: Vec<usize> },
    Transpose { perm: Vec<usize> },
    /// Static reshape; target dims recorded in the instruction type.
    Reshape,
    /// Dynamic reshape; target extents come from an i64 shape-tensor operand.
    DReshape,
    Concat { axis: usize },
    /// Static slice (HLO form, constant attributes).
    Slice { starts: Vec<i64>, limits: Vec<i64>, strides: Vec<i64> },
    /// Dynamic slice (DHLO form): operands are
    /// `(input, starts: s64[r], limits: s64[r], strides: s64[r])`.
    DSlice,
    /// Static pad; operands `(input, pad_value)`.
    Pad { low: Vec<i64>, high: Vec<i64> },
    /// Dynamic pad: operands `(input, pad_value, low: s64[r], high: s64[r])`.
    DPad,
    Reduce { kind: ReduceKind, axes: Vec<usize> },
    /// Matrix product: `[m,k]·[k,n] → [m,n]`, or batched
    /// `[b,m,k]·[b,k,n] → [b,m,n]`. Compute-intensive: routed through the
    /// kernel library (§4.5), never fused.
    Dot,
    /// `gather(x, idx)`: take rows of `x` along `axis` (embedding lookup).
    Gather { axis: usize },
    /// Iota along `axis`; output shape fixed at construction.
    Iota { axis: usize },
    /// `unique(x: s64[n]) → s64[u]` with data-dependent `u` — the sparse
    /// workload driver the paper cites (tf.Unique).
    Unique,
    /// Extent of `axis` as an s64 scalar (host-side shape calculation).
    GetDimSize { axis: usize },
}

impl Op {
    pub fn name(&self) -> String {
        match self {
            Op::Param { index } => format!("param{index}"),
            Op::Const { .. } => "constant".into(),
            Op::Un(k) => k.name().into(),
            Op::Bin(k) => k.name().into(),
            Op::Cmp(d) => format!("compare.{}", d.hlo_direction()),
            Op::Select => "select".into(),
            Op::Convert(t) => format!("convert.{t}"),
            Op::Broadcast { .. } => "broadcast_in_dim".into(),
            Op::DBroadcast { .. } => "d_broadcast_in_dim".into(),
            Op::Transpose { .. } => "transpose".into(),
            Op::Reshape => "reshape".into(),
            Op::DReshape => "d_reshape".into(),
            Op::Concat { .. } => "concatenate".into(),
            Op::Slice { .. } => "slice".into(),
            Op::DSlice => "d_slice".into(),
            Op::Pad { .. } => "pad".into(),
            Op::DPad => "d_pad".into(),
            Op::Reduce { kind, .. } => format!("reduce.{}", kind.name()),
            Op::Dot => "dot".into(),
            Op::Gather { .. } => "gather".into(),
            Op::Iota { .. } => "iota".into(),
            Op::Unique => "unique".into(),
            Op::GetDimSize { .. } => "get_dimension_size".into(),
        }
    }

    /// Compute-intensive ops go through the library (§4.5) and are excluded
    /// from fusion; everything else is memory-intensive (§2).
    pub fn is_compute_intensive(&self) -> bool {
        matches!(self, Op::Dot)
    }

    /// Whether this is one of the dynamic twins introduced by DHLO.
    pub fn is_dynamic_variant(&self) -> bool {
        matches!(self, Op::DSlice | Op::DPad | Op::DReshape | Op::DBroadcast { .. })
    }

    /// Shape-propagation class (the fusion planner's table, §4.3).
    pub fn prop_class(&self) -> PropClass {
        match self {
            Op::Un(_) | Op::Bin(_) | Op::Cmp(_) | Op::Select | Op::Convert(_) => {
                PropClass::ElementwiseSameShape
            }
            Op::Transpose { .. } | Op::Reshape | Op::DReshape => PropClass::SizePreserving,
            Op::Reduce { .. } => PropClass::Contracting,
            _ => PropClass::Opaque,
        }
    }

    /// Ops that can appear *inside* a fused kernel body (memory-intensive,
    /// expressible in the emitted HLO fusion body, and *bucket-safe*: with
    /// dynamic dims rounded up to bucket extents, the valid data always
    /// occupies the per-axis prefix box, so tail garbage can be masked at
    /// reduces and cropped at the root. Reshape is excluded — it scatters
    /// the valid box — and is instead executed as a free bitcast).
    pub fn is_fusable(&self) -> bool {
        matches!(
            self,
            Op::Un(_)
                | Op::Bin(_)
                | Op::Cmp(_)
                | Op::Select
                | Op::Convert(_)
                | Op::Broadcast { .. }
                | Op::Reduce { .. }
                | Op::Transpose { .. }
        )
    }

    /// Operand slots that carry *shape* information (s64 index tensors of
    /// the dynamic twins). The placer pins the producers of these operands
    /// to the host, mirroring DISC's host-side shape calculation.
    pub fn shape_operand_slots(&self) -> &'static [usize] {
        match self {
            Op::DSlice => &[1, 2, 3],
            Op::DPad => &[2, 3],
            Op::DReshape | Op::DBroadcast { .. } => &[1],
            _ => &[],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_table() {
        assert!(Op::Dot.is_compute_intensive());
        assert!(!Op::Bin(BinKind::Add).is_compute_intensive());
        assert_eq!(Op::Bin(BinKind::Add).prop_class(), PropClass::ElementwiseSameShape);
        // Add and Sub share a propagation class — the paper's example.
        assert_eq!(Op::Bin(BinKind::Sub).prop_class(), Op::Bin(BinKind::Add).prop_class());
        assert_eq!(Op::Transpose { perm: vec![1, 0] }.prop_class(), PropClass::SizePreserving);
        assert_eq!(
            Op::Reduce { kind: ReduceKind::Sum, axes: vec![1] }.prop_class(),
            PropClass::Contracting
        );
        assert_eq!(Op::Concat { axis: 0 }.prop_class(), PropClass::Opaque);
    }

    #[test]
    fn dynamic_twins() {
        assert!(Op::DSlice.is_dynamic_variant());
        assert!(!Op::Slice { starts: vec![], limits: vec![], strides: vec![] }
            .is_dynamic_variant());
        assert_eq!(Op::DSlice.shape_operand_slots(), &[1, 2, 3]);
        assert_eq!(Op::DPad.shape_operand_slots(), &[2, 3]);
        assert!(Op::Bin(BinKind::Mul).shape_operand_slots().is_empty());
    }

    #[test]
    fn reduce_neutrals() {
        assert_eq!(ReduceKind::Sum.neutral(), 0.0);
        assert_eq!(ReduceKind::Max.neutral(), f32::NEG_INFINITY);
        assert_eq!(ReduceKind::Min.neutral(), f32::INFINITY);
    }
}
