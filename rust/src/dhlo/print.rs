//! Textual form of DHLO modules (for logs, `disc inspect`, and golden tests).

use super::module::Module;
use super::op::Op;
use std::fmt::Write as _;

/// Render a module in an HLO-flavoured textual form:
///
/// ```text
/// module @name (arg0: f32[s0,768], arg1: f32[768]) -> (%5) {
///   %0 = param0 : f32[s0,768]
///   %1 = add(%0, %0) : f32[s0,768]
///   ...
/// }
/// ```
pub fn print_module(m: &Module) -> String {
    let mut out = String::new();
    let params: Vec<String> =
        m.params.iter().enumerate().map(|(i, t)| format!("arg{i}: {t}")).collect();
    let outs: Vec<String> = m.outputs.iter().map(|o| format!("%{o}")).collect();
    let _ = writeln!(out, "module @{} ({}) -> ({}) {{", m.name, params.join(", "), outs.join(", "));
    for (id, ins) in m.instrs.iter().enumerate() {
        let operands: Vec<String> = ins.operands.iter().map(|o| format!("%{o}")).collect();
        let attrs = attr_string(&ins.op);
        let name = ins.name.as_deref().map(|n| format!("  // {n}")).unwrap_or_default();
        let _ = writeln!(
            out,
            "  %{id} = {}({}){} : {}{name}",
            ins.op.name(),
            operands.join(", "),
            attrs,
            ins.ty
        );
    }
    out.push_str("}\n");
    if !m.syms.is_empty() {
        out.push_str("// shape symbols:\n");
        for line in m.syms.dump().lines() {
            let _ = writeln!(out, "//   {line}");
        }
    }
    out
}

fn attr_string(op: &Op) -> String {
    match op {
        Op::Broadcast { dims } | Op::DBroadcast { dims } => format!(" dims={dims:?}"),
        Op::Transpose { perm } => format!(" perm={perm:?}"),
        Op::Concat { axis } => format!(" axis={axis}"),
        Op::Slice { starts, limits, strides } => {
            format!(" starts={starts:?} limits={limits:?} strides={strides:?}")
        }
        Op::Pad { low, high } => format!(" low={low:?} high={high:?}"),
        Op::Reduce { axes, .. } => format!(" axes={axes:?}"),
        Op::Gather { axis } => format!(" axis={axis}"),
        Op::Iota { axis } => format!(" axis={axis}"),
        Op::GetDimSize { axis } => format!(" axis={axis}"),
        Op::Const { dims, .. } => format!(" dims={dims:?}"),
        _ => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType};
    use crate::shape::Dim;

    #[test]
    fn prints_readable_module() {
        let mut b = Builder::new("demo");
        let s = b.dyn_dim("seq", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let y = b.unary(crate::dhlo::UnKind::Tanh, x);
        b.set_name(y, "activation");
        let z = b.add(x, y).unwrap();
        let m = b.finish(vec![z]);
        let text = print_module(&m);
        assert!(text.contains("module @demo"));
        assert!(text.contains("tanh(%0) : f32[s0,4]  // activation"));
        assert!(text.contains("add(%0, %1)"));
        assert!(text.contains("-> (%2)"));
        assert!(text.contains("shape symbols"));
    }
}
