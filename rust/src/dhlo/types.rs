//! Tensor element types, tensor types with symbolic dims, and literals.

use crate::shape::{Dim, SymbolTable};
use std::fmt;

/// Element types supported by the pipeline end-to-end (IR → HLO text → PJRT).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I64,
    I32,
    /// Boolean / predicate (HLO `pred`).
    Pred,
}

impl DType {
    /// The HLO-text name of this element type.
    pub fn hlo_name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I64 => "s64",
            DType::I32 => "s32",
            DType::Pred => "pred",
        }
    }

    pub fn byte_size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
            DType::Pred => 1,
        }
    }

    pub fn is_float(&self) -> bool {
        matches!(self, DType::F32)
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.hlo_name())
    }
}

/// A tensor type: element type plus a (possibly symbolic) dim vector.
/// DISC targets dynamic *shapes* with static *rank* (§2), so the rank is
/// always known here.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    pub dtype: DType,
    pub dims: Vec<Dim>,
}

impl TensorType {
    pub fn new(dtype: DType, dims: Vec<Dim>) -> Self {
        TensorType { dtype, dims }
    }

    /// Fully-static tensor type.
    pub fn fixed(dtype: DType, dims: &[usize]) -> Self {
        TensorType { dtype, dims: dims.iter().map(|&d| Dim::Fixed(d)).collect() }
    }

    pub fn scalar(dtype: DType) -> Self {
        TensorType { dtype, dims: vec![] }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn is_static(&self) -> bool {
        self.dims.iter().all(|d| !d.is_dynamic())
    }

    /// Element count if fully static (`Some(1)` for scalars, `None` if any
    /// dim is symbolic).
    pub fn static_elems(&self) -> Option<usize> {
        self.dims.iter().map(|d| d.fixed()).product::<Option<usize>>()
    }

    /// Canonicalize all dims through the symbol table (used when comparing
    /// shapes for fusion decisions).
    pub fn canon(&self, syms: &SymbolTable) -> TensorType {
        TensorType {
            dtype: self.dtype,
            dims: self.dims.iter().map(|&d| syms.canon_dim(d)).collect(),
        }
    }
}

impl fmt::Display for TensorType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[", self.dtype)?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// A constant tensor value (always fully static).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32(Vec<f32>),
    I64(Vec<i64>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Literal {
    pub fn dtype(&self) -> DType {
        match self {
            Literal::F32(_) => DType::F32,
            Literal::I64(_) => DType::I64,
            Literal::I32(_) => DType::I32,
            Literal::Pred(_) => DType::Pred,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Literal::F32(v) => v.len(),
            Literal::I64(v) => v.len(),
            Literal::I32(v) => v.len(),
            Literal::Pred(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn scalar_f32(v: f32) -> Literal {
        Literal::F32(vec![v])
    }

    pub fn scalar_i64(v: i64) -> Literal {
        Literal::I64(vec![v])
    }

    /// Render elements in HLO-text constant syntax (flat list; the caller
    /// adds the braces appropriate to the rank).
    pub fn hlo_elems(&self) -> Vec<String> {
        match self {
            Literal::F32(v) => v.iter().map(|x| format_f32_hlo(*x)).collect(),
            Literal::I64(v) => v.iter().map(|x| x.to_string()).collect(),
            Literal::I32(v) => v.iter().map(|x| x.to_string()).collect(),
            Literal::Pred(v) => {
                v.iter().map(|x| if *x { "true".into() } else { "false".into() }).collect()
            }
        }
    }
}

/// HLO text floats must round-trip exactly; `{:?}` gives shortest-precise
/// formatting for f32 and HLO's parser accepts it (inf/nan spelled out).
pub fn format_f32_hlo(x: f32) -> String {
    if x.is_infinite() {
        return if x > 0.0 { "inf".into() } else { "-inf".into() };
    }
    if x.is_nan() {
        return "nan".into();
    }
    let s = format!("{x:?}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shape::{ShapeExpr, SymbolTable};

    #[test]
    fn display_forms() {
        let t = TensorType::fixed(DType::F32, &[2, 3]);
        assert_eq!(t.to_string(), "f32[2,3]");
        let mut syms = SymbolTable::new();
        let s = syms.fresh("seq", ShapeExpr::InputDim { param: 0, axis: 0 });
        let d = TensorType::new(DType::F32, vec![Dim::Sym(s), Dim::Fixed(768)]);
        assert_eq!(d.to_string(), "f32[s0,768]");
        assert!(!d.is_static());
        assert!(t.is_static());
    }

    #[test]
    fn static_elems() {
        assert_eq!(TensorType::fixed(DType::F32, &[2, 3]).static_elems(), Some(6));
        assert_eq!(TensorType::scalar(DType::I64).static_elems(), Some(1));
        let mut syms = SymbolTable::new();
        let s = syms.fresh("n", ShapeExpr::InputDim { param: 0, axis: 0 });
        let d = TensorType::new(DType::F32, vec![Dim::Sym(s)]);
        assert_eq!(d.static_elems(), None);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(format_f32_hlo(1.0), "1.0");
        assert_eq!(format_f32_hlo(-0.5), "-0.5");
        assert_eq!(format_f32_hlo(f32::INFINITY), "inf");
        assert_eq!(format_f32_hlo(f32::NEG_INFINITY), "-inf");
        // Round-trips through parse.
        let v = 0.1234567f32;
        assert_eq!(format_f32_hlo(v).parse::<f32>().unwrap(), v);
    }

    #[test]
    fn literal_basics() {
        let l = Literal::F32(vec![1.0, 2.5]);
        assert_eq!(l.dtype(), DType::F32);
        assert_eq!(l.len(), 2);
        assert_eq!(l.hlo_elems(), vec!["1.0", "2.5"]);
        let b = Literal::Pred(vec![true, false]);
        assert_eq!(b.hlo_elems(), vec!["true", "false"]);
    }
}
