//! DHLO — the dynamic-shape IR at the center of DISC (§4.1).
//!
//! DHLO is an HLO-dialect-like SSA IR in which tensor dimensions may be
//! *symbolic* ([`crate::shape::Dim::Sym`]). Following the paper's "IR
//! supplementation" design, ops whose HLO form carries constant-folded shape
//! attributes get a dynamic twin whose indices arrive as *tensor operands*
//! instead (figure 2 of the paper): [`op::Op::DSlice`], [`op::Op::DPad`],
//! [`op::Op::DReshape`], [`op::Op::DBroadcast`]. Ops whose HLO definition is
//! already expressive enough for dynamic shapes (elementwise `Add`/`Mul`,
//! `Dot`, `Reduce`, …) are kept as they are — DHLO is an extension, not a
//! replacement.
//!
//! A [`module::Module`] owns its instructions (topologically ordered SSA),
//! its entry parameter types, and the [`crate::shape::SymbolTable`] holding
//! the shape constraints collected so far.

pub mod module;
pub mod parse;
pub mod op;
pub mod print;
pub mod types;
pub mod verify;

pub use module::{Builder, Instr, Module, ValueId};
pub use op::{BinKind, CmpDir, Op, ReduceKind, UnKind};
pub use types::{DType, Literal, TensorType};
