//! Parser for the DHLO textual form emitted by [`super::print`].
//!
//! Round-trips `print_module` output (modulo symbol *definitions*, which are
//! re-derived: parsing re-runs the builder so op-semantic constraints are
//! re-collected; bridge-injected extras are re-applied from the printed
//! constraint-class comments). Used by `disc inspect --file x.dhlo` and the
//! golden round-trip tests.

use super::module::{Builder, Module, ValueId};
use super::op::{BinKind, CmpDir, Op, ReduceKind, UnKind};
use super::types::{DType, Literal};
use crate::shape::Dim;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

fn parse_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "f32" => DType::F32,
        "s64" => DType::I64,
        "s32" => DType::I32,
        "pred" => DType::Pred,
        other => bail!("unknown dtype '{other}'"),
    })
}

/// Parse `f32[s0,768]`-style types. Symbolic dims are named `s<N>`; the
/// name table maps them to freshly minted symbols.
fn parse_type(
    s: &str,
    b: &mut Builder,
    sym_names: &mut HashMap<String, Dim>,
    param_hint: Option<(usize, usize)>,
) -> Result<(DType, Vec<Dim>)> {
    let open = s.find('[').context("type needs '['")?;
    let dtype = parse_dtype(&s[..open])?;
    let inner = s[open + 1..].trim_end_matches(']');
    let mut dims = Vec::new();
    if !inner.is_empty() {
        for (axis, part) in inner.split(',').enumerate() {
            let part = part.trim();
            if let Ok(n) = part.parse::<usize>() {
                dims.push(Dim::Fixed(n));
            } else if let Some(d) = sym_names.get(part) {
                dims.push(*d);
            } else {
                // Fresh symbol; bind to the input dim when this is a
                // parameter type. Otherwise use an unresolvable sentinel
                // definition (NOT a constant — constants collapse to Fixed
                // in canon_dim): the post-registration pass unifies the
                // name with the builder-minted symbol, whose real
                // definition then wins when it becomes the representative.
                let def = match param_hint {
                    Some((p, _)) => crate::shape::ShapeExpr::InputDim { param: p, axis },
                    None => crate::shape::ShapeExpr::InputDim { param: usize::MAX, axis },
                };
                let sym = b.m.syms.fresh(part.to_string(), def);
                let d = Dim::Sym(sym);
                sym_names.insert(part.to_string(), d);
                dims.push(d);
            }
        }
    }
    Ok((dtype, dims))
}

fn parse_attr_list(s: &str) -> Vec<i64> {
    s.trim_start_matches('[')
        .trim_end_matches(']')
        .split(',')
        .filter_map(|p| p.trim().parse::<i64>().ok())
        .collect()
}

/// Extract `name=value` attrs (values are `[..]` lists or scalars). The
/// printer uses Debug list formatting (`[0, 2]`), so inner ", " is
/// collapsed before whitespace-splitting.
fn attrs_of(rest: &str) -> HashMap<String, String> {
    let compact = rest.replace(", ", ",");
    let mut out = HashMap::new();
    for piece in compact.split_whitespace() {
        if let Some((k, v)) = piece.split_once('=') {
            out.insert(k.to_string(), v.to_string());
        }
    }
    out
}

/// Parse a module printed by [`super::print::print_module`].
pub fn parse_module(text: &str) -> Result<Module> {
    let mut lines = text.lines().peekable();
    let header = lines.next().context("empty module text")?;
    ensure!(header.starts_with("module @"), "expected 'module @...' header");
    let name = header
        .trim_start_matches("module @")
        .split(' ')
        .next()
        .unwrap_or("parsed")
        .to_string();

    // Output list: "... -> (%a, %b) {"
    let outs_str = header
        .split("-> (")
        .nth(1)
        .and_then(|s| s.split(')').next())
        .context("header outputs")?;
    let outputs: Vec<ValueId> = outs_str
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().trim_start_matches('%').parse::<usize>().context("output id"))
        .collect::<Result<_>>()?;

    let mut b = Builder::new(name);
    let mut sym_names: HashMap<String, Dim> = HashMap::new();
    let mut next_param = 0usize;

    for line in lines {
        let line = line.trim();
        if line.is_empty() || line.starts_with('}') || line.starts_with("//") {
            continue;
        }
        // "%3 = add(%1, %2) : f32[s0,8]" possibly with attrs before ':'.
        let (lhs, rhs) = line.split_once(" = ").context("instruction '='")?;
        let id: usize = lhs.trim_start_matches('%').parse().context("value id")?;
        let (body, ty_and_name) = rhs.rsplit_once(" : ").context("type separator")?;
        let ty_str = ty_and_name.split("  //").next().unwrap_or(ty_and_name).trim();

        let open = body.find('(').context("op open paren")?;
        let opname = &body[..open];
        let close = body.rfind(')').context("op close paren")?;
        let operand_str = &body[open + 1..close];
        let attrs = attrs_of(&body[close + 1..]);
        let operands: Vec<ValueId> = operand_str
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().trim_start_matches('%').parse::<usize>().context("operand"))
            .collect::<Result<_>>()?;

        let made: ValueId = match opname {
            p if p.starts_with("param") => {
                let idx = next_param;
                next_param += 1;
                let (dt, dims) =
                    parse_type(ty_str, &mut b, &mut sym_names, Some((idx, 0)))?;
                b.param(dt, dims)
            }
            "constant" => {
                // Constants print only their dims; values are not embedded
                // in the textual form (they may be megabytes). Re-parse as
                // zeros of the right shape — the round-trip contract covers
                // structure, not weights (weights travel via artifacts).
                let (dt, dims) = parse_type(ty_str, &mut b, &mut sym_names, None)?;
                let fixed: Vec<usize> =
                    dims.iter().map(|d| d.fixed().context("const dims")).collect::<Result<_>>()?;
                let n: usize = fixed.iter().product::<usize>().max(1);
                let lit = match dt {
                    DType::F32 => Literal::F32(vec![0.0; n]),
                    DType::I64 => Literal::I64(vec![0; n]),
                    DType::I32 => Literal::I32(vec![0; n]),
                    DType::Pred => Literal::Pred(vec![false; n]),
                };
                b.constant(lit, &fixed)
            }
            "abs" => b.unary(UnKind::Abs, operands[0]),
            "negate" => b.unary(UnKind::Neg, operands[0]),
            "exponential" => b.unary(UnKind::Exp, operands[0]),
            "log" => b.unary(UnKind::Log, operands[0]),
            "tanh" => b.unary(UnKind::Tanh, operands[0]),
            "sqrt" => b.unary(UnKind::Sqrt, operands[0]),
            "rsqrt" => b.unary(UnKind::Rsqrt, operands[0]),
            "logistic" => b.unary(UnKind::Sigmoid, operands[0]),
            "relu" => b.unary(UnKind::Relu, operands[0]),
            "gelu" => b.unary(UnKind::Gelu, operands[0]),
            "erf" => b.unary(UnKind::Erf, operands[0]),
            "floor" => b.unary(UnKind::Floor, operands[0]),
            "sign" => b.unary(UnKind::Sign, operands[0]),
            "add" => b.add(operands[0], operands[1])?,
            "subtract" => b.sub(operands[0], operands[1])?,
            "multiply" => b.mul(operands[0], operands[1])?,
            "divide" => b.div(operands[0], operands[1])?,
            "maximum" => b.maximum(operands[0], operands[1])?,
            "minimum" => b.binary(BinKind::Min, operands[0], operands[1])?,
            "power" => b.binary(BinKind::Pow, operands[0], operands[1])?,
            s if s.starts_with("compare.") => {
                let dir = match &s[8..] {
                    "EQ" => CmpDir::Eq,
                    "NE" => CmpDir::Ne,
                    "LT" => CmpDir::Lt,
                    "LE" => CmpDir::Le,
                    "GT" => CmpDir::Gt,
                    "GE" => CmpDir::Ge,
                    o => bail!("compare direction {o}"),
                };
                b.compare(dir, operands[0], operands[1])?
            }
            "select" => b.select(operands[0], operands[1], operands[2])?,
            s if s.starts_with("convert.") => {
                b.convert(operands[0], parse_dtype(&s[8..])?)
            }
            "broadcast_in_dim" => {
                let mapping: Vec<usize> = parse_attr_list(
                    attrs.get("dims").context("broadcast dims attr")?,
                )
                .into_iter()
                .map(|x| x as usize)
                .collect();
                let (_, out_dims) = parse_type(ty_str, &mut b, &mut sym_names, None)?;
                b.broadcast(operands[0], out_dims, mapping)?
            }
            "transpose" => {
                let perm: Vec<usize> =
                    parse_attr_list(attrs.get("perm").context("perm")?)
                        .into_iter()
                        .map(|x| x as usize)
                        .collect();
                b.transpose(operands[0], perm)?
            }
            "reshape" => {
                let (_, out_dims) = parse_type(ty_str, &mut b, &mut sym_names, None)?;
                b.reshape(operands[0], out_dims)?
            }
            "d_reshape" => {
                let (_, out_dims) = parse_type(ty_str, &mut b, &mut sym_names, None)?;
                let rank = out_dims.len();
                b.dreshape(operands[0], operands[1], rank)?
            }
            "concatenate" => {
                let axis = attrs.get("axis").context("axis")?.parse::<usize>()?;
                b.concat(&operands, axis)?
            }
            "slice" => {
                let starts = parse_attr_list(attrs.get("starts").context("starts")?);
                let limits = parse_attr_list(attrs.get("limits").context("limits")?);
                let strides = parse_attr_list(attrs.get("strides").context("strides")?);
                b.slice(operands[0], starts, limits, strides)?
            }
            "d_slice" => b.dslice(operands[0], operands[1], operands[2], operands[3])?,
            "pad" => {
                let low = parse_attr_list(attrs.get("low").context("low")?);
                let high = parse_attr_list(attrs.get("high").context("high")?);
                b.pad(operands[0], operands[1], low, high)?
            }
            "d_pad" => b.dpad(operands[0], operands[1], operands[2], operands[3])?,
            s if s.starts_with("reduce.") => {
                let kind = match &s[7..] {
                    "sum" => ReduceKind::Sum,
                    "max" => ReduceKind::Max,
                    "min" => ReduceKind::Min,
                    "mean" => ReduceKind::Mean,
                    o => bail!("reduce kind {o}"),
                };
                let axes: Vec<usize> = parse_attr_list(attrs.get("axes").context("axes")?)
                    .into_iter()
                    .map(|x| x as usize)
                    .collect();
                b.reduce(kind, operands[0], axes)?
            }
            "dot" => b.dot(operands[0], operands[1])?,
            "gather" => {
                let axis = attrs.get("axis").context("axis")?.parse::<usize>()?;
                b.gather(operands[0], operands[1], axis)?
            }
            "iota" => {
                let axis = attrs.get("axis").context("axis")?.parse::<usize>()?;
                let (dt, dims) = parse_type(ty_str, &mut b, &mut sym_names, None)?;
                b.iota(dt, dims, axis)?
            }
            "unique" => b.unique(operands[0])?,
            "get_dimension_size" => {
                let axis = attrs.get("axis").context("axis")?.parse::<usize>()?;
                b.get_dim_size(operands[0], axis)?
            }
            other => bail!("unknown op '{other}'"),
        };
        ensure!(made == id, "instruction id mismatch: printed %{id}, rebuilt %{made}");
        // Register the result type's symbolic dims under their printed
        // names so later references resolve to the same symbols.
        let printed = ty_str.split("  //").next().unwrap_or(ty_str);
        if let Some(open) = printed.find('[') {
            let inner = printed[open + 1..].trim_end_matches(']');
            for (axis, part) in inner.split(',').enumerate() {
                let part = part.trim();
                if part.starts_with('s') && part[1..].chars().all(|c| c.is_ascii_digit()) {
                    let actual = b.m.ty(made).dims.get(axis).copied();
                    if let Some(d) = actual {
                        sym_names.entry(part.to_string()).or_insert(d);
                        // Printed alias and rebuilt dim must unify.
                        if let (Some(Dim::Sym(a)), Dim::Sym(bb)) =
                            (sym_names.get(part).copied(), d)
                        {
                            b.m.syms.unify(a, bb);
                        }
                    }
                }
            }
        }
    }

    let m = b.finish(outputs);
    super::verify::verify(&m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::print::print_module;

    fn roundtrip(m: &Module) -> Module {
        let text = print_module(m);
        parse_module(&text).unwrap_or_else(|e| panic!("parse failed: {e:#}\n{text}"))
    }

    #[test]
    fn roundtrip_elementwise_chain() {
        let mut b = Builder::new("rt");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(x, t).unwrap();
        let m = b.finish(vec![y]);
        let m2 = roundtrip(&m);
        assert_eq!(m.instrs.len(), m2.instrs.len());
        for (a, bb) in m.instrs.iter().zip(&m2.instrs) {
            assert_eq!(a.op.name(), bb.op.name());
            assert_eq!(a.operands, bb.operands);
        }
        assert_eq!(m.outputs, m2.outputs);
    }

    #[test]
    fn roundtrip_softmax_and_reduce() {
        let mut b = Builder::new("rt2");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let sm = b.softmax_last(x).unwrap();
        let r = b.reduce(ReduceKind::Mean, sm, vec![1]).unwrap();
        let m = b.finish(vec![sm, r]);
        let m2 = roundtrip(&m);
        assert_eq!(m.instrs.len(), m2.instrs.len());
        // Numerics agree (structure-preserving parse).
        let input = crate::runtime::tensor::Tensor::f32(
            &[3, 8],
            (0..24).map(|i| i as f32 * 0.1).collect(),
        );
        let a = crate::runtime::reference::eval_module(&m, &[input.clone()]).unwrap();
        let c = crate::runtime::reference::eval_module(&m2, &[input]).unwrap();
        assert!(a.outputs[0].allclose(&c.outputs[0], 1e-6, 1e-6).unwrap());
    }

    #[test]
    fn roundtrip_dynamic_twins() {
        let mut b = Builder::new("rt3");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let st = b.i64_vec(&[1]);
        let li = b.i64_vec(&[3]);
        let sr = b.i64_vec(&[1]);
        let sl = b.dslice(x, st, li, sr).unwrap();
        let m = b.finish(vec![sl]);
        let m2 = roundtrip(&m);
        assert!(m2.instrs.iter().any(|i| matches!(i.op, Op::DSlice)));
    }

    #[test]
    fn roundtrip_workload_modules() {
        // Structural round-trip over the real workload graphs (constants
        // are re-materialized as zeros; structure and ops must survive).
        for w in crate::workloads::all() {
            let m = crate::bridge::lower(&w.graph).unwrap();
            let text = print_module(&m);
            let m2 = parse_module(&text)
                .unwrap_or_else(|e| panic!("{}: parse failed: {e:#}", w.name));
            assert_eq!(m.instrs.len(), m2.instrs.len(), "{}", w.name);
            assert_eq!(m.outputs, m2.outputs, "{}", w.name);
            for (a, bb) in m.instrs.iter().zip(&m2.instrs) {
                assert_eq!(a.op.name(), bb.op.name(), "{}", w.name);
            }
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_module("garbage").is_err());
        assert!(parse_module("module @x () -> (%0) {\n  %0 = nope() : f32[]\n}").is_err());
    }
}
