//! DHLO modules, instructions, and the typed builder.
//!
//! The builder performs shape inference *during construction* and records
//! the paper's op-semantic shape constraints as it goes (§4.2.1, first
//! source): a binary elementwise op unifies the symbolic dims of its
//! operands; `Transpose`/`Reshape` record tensor-size equality; `Concat`
//! derives a sum expression for the concatenated axis; the dynamic twins
//! (`DSlice`, `DPad`, …) mint symbols whose definitions read runtime shape
//! tensors. Bridge-injected constraints (the paper's second source) are
//! added afterwards via [`Module::inject_dim_equality`] /
//! [`Module::inject_size_equality`].

use super::op::{BinKind, CmpDir, Op, ReduceKind, UnKind};
use super::types::{DType, Literal, TensorType};
use crate::shape::{Dim, ShapeExpr, SymbolTable};
use anyhow::{bail, ensure, Result};

/// SSA value id: index into [`Module::instrs`].
pub type ValueId = usize;

/// One SSA instruction.
#[derive(Debug, Clone)]
pub struct Instr {
    pub op: Op,
    pub operands: Vec<ValueId>,
    pub ty: TensorType,
    /// Optional debug name, carried from the frontend graph.
    pub name: Option<String>,
}

/// A DHLO module: topologically-ordered SSA instructions, entry parameter
/// types, module outputs, and the symbol/constraint store.
#[derive(Debug, Clone, Default)]
pub struct Module {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub params: Vec<TensorType>,
    pub outputs: Vec<ValueId>,
    pub syms: SymbolTable,
}

impl Module {
    pub fn ty(&self, v: ValueId) -> &TensorType {
        &self.instrs[v].ty
    }

    pub fn op(&self, v: ValueId) -> &Op {
        &self.instrs[v].op
    }

    /// Users of each value (recomputed on demand; modules are small).
    pub fn users(&self) -> Vec<Vec<ValueId>> {
        let mut users = vec![Vec::new(); self.instrs.len()];
        for (id, ins) in self.instrs.iter().enumerate() {
            for &o in &ins.operands {
                users[o].push(id);
            }
        }
        users
    }

    /// Inject a dimension-size equality constraint discovered by the bridge
    /// (§4.2.1 second source, e.g. `tf.Split` siblings).
    pub fn inject_dim_equality(&mut self, a: Dim, b: Dim) {
        if let (Dim::Sym(sa), Dim::Sym(sb)) = (a, b) {
            self.syms.unify(sa, sb);
        }
    }

    /// Inject a tensor-size equality constraint discovered by the bridge.
    pub fn inject_size_equality(&mut self, a: ValueId, b: ValueId) {
        self.syms.record_size_equal(a, b);
    }

    /// Values that are provably the same shape under collected constraints.
    pub fn same_shape(&self, a: ValueId, b: ValueId) -> bool {
        self.ty(a).dtype == self.ty(b).dtype
            && self.syms.shapes_equal(&self.ty(a).dims, &self.ty(b).dims)
    }

    /// Values provably holding the same number of elements: either their
    /// canonical dim vectors match, or a size-equality was recorded.
    pub fn same_size(&self, a: ValueId, b: ValueId) -> bool {
        self.syms.shapes_equal(&self.ty(a).dims, &self.ty(b).dims)
            || self.syms.size_equal(a, b)
            || match (self.ty(a).static_elems(), self.ty(b).static_elems()) {
                (Some(x), Some(y)) => x == y,
                _ => false,
            }
    }

    /// True if every instruction (and thus the whole module) is static —
    /// used by the mixed static/dynamic pipeline to fall back (§4.4).
    pub fn is_fully_static(&self) -> bool {
        self.instrs.iter().all(|i| i.ty.canon(&self.syms).is_static())
    }

    /// Count of memory-intensive (fusable-class) tensor ops, for metrics.
    pub fn memory_intensive_count(&self) -> usize {
        self.instrs
            .iter()
            .filter(|i| {
                !i.op.is_compute_intensive()
                    && !matches!(i.op, Op::Param { .. } | Op::Const { .. })
            })
            .count()
    }
}

/// Typed builder over a [`Module`].
pub struct Builder {
    pub m: Module,
}

impl Builder {
    pub fn new(name: impl Into<String>) -> Self {
        Builder { m: Module { name: name.into(), ..Default::default() } }
    }

    pub fn finish(mut self, outputs: Vec<ValueId>) -> Module {
        self.m.outputs = outputs;
        self.m
    }

    fn push(&mut self, op: Op, operands: Vec<ValueId>, ty: TensorType) -> ValueId {
        self.m.instrs.push(Instr { op, operands, ty, name: None });
        self.m.instrs.len() - 1
    }

    pub fn set_name(&mut self, v: ValueId, name: impl Into<String>) {
        self.m.instrs[v].name = Some(name.into());
    }

    fn ty(&self, v: ValueId) -> &TensorType {
        &self.m.instrs[v].ty
    }

    // ---- parameters & constants -------------------------------------------

    /// Declare an entry parameter. Symbolic dims must already be minted via
    /// [`Builder::dyn_dim`] (so their definitions point at input extents).
    pub fn param(&mut self, dtype: DType, dims: Vec<Dim>) -> ValueId {
        let index = self.m.params.len();
        let ty = TensorType::new(dtype, dims);
        self.m.params.push(ty.clone());
        self.push(Op::Param { index }, vec![], ty)
    }

    /// Mint a symbol bound to `axis` of the *next* parameter index `param`.
    pub fn dyn_dim(&mut self, name: impl Into<String>, param: usize, axis: usize) -> Dim {
        Dim::Sym(self.m.syms.fresh(name, ShapeExpr::InputDim { param, axis }))
    }

    pub fn constant(&mut self, lit: Literal, dims: &[usize]) -> ValueId {
        let n: usize = dims.iter().product::<usize>().max(1);
        assert_eq!(lit.len(), n, "constant literal length mismatch");
        let ty = TensorType::fixed(lit.dtype(), dims);
        self.push(Op::Const { lit, dims: dims.to_vec() }, vec![], ty)
    }

    pub fn scalar_f32(&mut self, v: f32) -> ValueId {
        self.constant(Literal::F32(vec![v]), &[])
    }

    pub fn scalar_i64(&mut self, v: i64) -> ValueId {
        self.constant(Literal::I64(vec![v]), &[])
    }

    pub fn i64_vec(&mut self, vals: &[i64]) -> ValueId {
        self.constant(Literal::I64(vals.to_vec()), &[vals.len()])
    }

    // ---- elementwise -------------------------------------------------------

    pub fn unary(&mut self, k: UnKind, x: ValueId) -> ValueId {
        let ty = self.ty(x).clone();
        let id = self.push(Op::Un(k), vec![x], ty);
        // Elementwise ops trivially preserve element count; recording it
        // makes tensor-size equality transitive across reshapes.
        self.m.syms.record_size_equal(x, id);
        id
    }

    /// Binary elementwise op. Operand shapes must agree rank-wise; symbolic
    /// dims are *unified* — the op-semantic constraint source of §4.2.1.
    pub fn binary(&mut self, k: BinKind, a: ValueId, b: ValueId) -> Result<ValueId> {
        let (ta, tb) = (self.ty(a).clone(), self.ty(b).clone());
        ensure!(ta.dtype == tb.dtype, "binary {k:?}: dtype mismatch {ta} vs {tb}");
        ensure!(ta.rank() == tb.rank(), "binary {k:?}: rank mismatch {ta} vs {tb}");
        let dims = self.unify_shapes(&ta.dims, &tb.dims)?;
        let id = self.push(Op::Bin(k), vec![a, b], TensorType::new(ta.dtype, dims));
        self.m.syms.record_size_equal(a, id);
        self.m.syms.record_size_equal(b, id);
        Ok(id)
    }

    pub fn add(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        self.binary(BinKind::Add, a, b)
    }
    pub fn sub(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        self.binary(BinKind::Sub, a, b)
    }
    pub fn mul(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        self.binary(BinKind::Mul, a, b)
    }
    pub fn div(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        self.binary(BinKind::Div, a, b)
    }
    pub fn maximum(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        self.binary(BinKind::Max, a, b)
    }

    pub fn compare(&mut self, dir: CmpDir, a: ValueId, b: ValueId) -> Result<ValueId> {
        let (ta, tb) = (self.ty(a).clone(), self.ty(b).clone());
        ensure!(ta.dtype == tb.dtype, "compare: dtype mismatch");
        ensure!(ta.rank() == tb.rank(), "compare: rank mismatch");
        let dims = self.unify_shapes(&ta.dims, &tb.dims)?;
        Ok(self.push(Op::Cmp(dir), vec![a, b], TensorType::new(DType::Pred, dims)))
    }

    pub fn select(&mut self, pred: ValueId, t: ValueId, f: ValueId) -> Result<ValueId> {
        ensure!(self.ty(pred).dtype == DType::Pred, "select: pred must be pred-typed");
        let (tt, tf) = (self.ty(t).clone(), self.ty(f).clone());
        ensure!(tt.dtype == tf.dtype, "select: branch dtype mismatch");
        let dims = self.unify_shapes(&tt.dims, &tf.dims)?;
        let pdims = self.ty(pred).dims.clone();
        let dims = self.unify_shapes(&dims, &pdims)?;
        Ok(self.push(Op::Select, vec![pred, t, f], TensorType::new(tt.dtype, dims)))
    }

    pub fn convert(&mut self, x: ValueId, to: DType) -> ValueId {
        let dims = self.ty(x).dims.clone();
        self.push(Op::Convert(to), vec![x], TensorType::new(to, dims))
    }

    /// Unify two dim vectors, recording equality constraints; returns the
    /// canonical merged dims. Errors if two *fixed* dims conflict.
    fn unify_shapes(&mut self, a: &[Dim], b: &[Dim]) -> Result<Vec<Dim>> {
        ensure!(a.len() == b.len(), "rank mismatch in unify");
        let mut out = Vec::with_capacity(a.len());
        for (&da, &db) in a.iter().zip(b) {
            let (ca, cb) = (self.m.syms.canon_dim(da), self.m.syms.canon_dim(db));
            let merged = match (ca, cb) {
                (Dim::Fixed(x), Dim::Fixed(y)) => {
                    ensure!(x == y, "dim mismatch {x} vs {y}");
                    Dim::Fixed(x)
                }
                (Dim::Sym(s), Dim::Sym(t)) => {
                    self.m.syms.unify(s, t);
                    self.m.syms.canon_dim(Dim::Sym(s))
                }
                // Fixed vs symbolic: the op requires them equal, so the
                // symbol is refined to the constant.
                (Dim::Fixed(x), Dim::Sym(s)) | (Dim::Sym(s), Dim::Fixed(x)) => {
                    let refined = self.m.syms.fresh(
                        format!("refine_{}", self.m.syms.name(s)),
                        ShapeExpr::Const(x as i64),
                    );
                    self.m.syms.unify(s, refined);
                    Dim::Fixed(x)
                }
            };
            out.push(merged);
        }
        Ok(out)
    }

    // ---- broadcast / layout -----------------------------------------------

    /// `broadcast_in_dim` to an explicit output shape. `mapping[i]` gives
    /// the output axis that operand axis `i` occupies.
    pub fn broadcast(
        &mut self,
        x: ValueId,
        out_dims: Vec<Dim>,
        mapping: Vec<usize>,
    ) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(mapping.len() == tx.rank(), "broadcast: mapping rank mismatch");
        for (i, &m) in mapping.iter().enumerate() {
            ensure!(m < out_dims.len(), "broadcast: mapping axis out of range");
            // Mapped dims must agree (or be 1 in the operand).
            if tx.dims[i].fixed() != Some(1) {
                let merged = self.unify_shapes(&[tx.dims[i]], &[out_dims[m]])?;
                let _ = merged;
            }
        }
        Ok(self.push(Op::Broadcast { dims: mapping }, vec![x], TensorType::new(tx.dtype, out_dims)))
    }

    /// Broadcast a scalar to the shape of `like`.
    pub fn broadcast_scalar_like(&mut self, scalar: ValueId, like: ValueId) -> Result<ValueId> {
        ensure!(self.ty(scalar).rank() == 0, "expected scalar");
        let out = self.ty(like).dims.clone();
        self.broadcast(scalar, out, vec![])
    }

    /// Dynamic broadcast: output extents read from `shape: s64[r]`.
    pub fn dbroadcast(
        &mut self,
        x: ValueId,
        shape: ValueId,
        mapping: Vec<usize>,
        out_rank: usize,
    ) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(self.ty(shape).dtype == DType::I64, "dbroadcast: shape tensor must be s64");
        let mut dims = Vec::with_capacity(out_rank);
        for axis in 0..out_rank {
            let s = self.m.syms.fresh(
                format!("dbc{}_{axis}", self.m.instrs.len()),
                ShapeExpr::Elem { value: shape, index: axis },
            );
            dims.push(Dim::Sym(s));
        }
        Ok(self.push(
            Op::DBroadcast { dims: mapping },
            vec![x, shape],
            TensorType::new(tx.dtype, dims),
        ))
    }

    pub fn transpose(&mut self, x: ValueId, perm: Vec<usize>) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(perm.len() == tx.rank(), "transpose: perm rank mismatch");
        let mut seen = vec![false; perm.len()];
        for &p in &perm {
            ensure!(p < perm.len() && !seen[p], "transpose: invalid perm");
            seen[p] = true;
        }
        let dims: Vec<Dim> = perm.iter().map(|&p| tx.dims[p]).collect();
        let id = self.push(Op::Transpose { perm }, vec![x], TensorType::new(tx.dtype, dims));
        // Op-semantic tensor-size equality (§4.2.1).
        self.m.syms.record_size_equal(x, id);
        Ok(id)
    }

    /// Static-target reshape. If both sides are fully static the element
    /// counts must match; with symbolic dims the tensor-size equality is
    /// recorded as a constraint instead.
    pub fn reshape(&mut self, x: ValueId, dims: Vec<Dim>) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        let out = TensorType::new(tx.dtype, dims);
        if let (Some(a), Some(b)) = (tx.static_elems(), out.static_elems()) {
            ensure!(a == b, "reshape: element count mismatch {a} vs {b}");
        }
        let id = self.push(Op::Reshape, vec![x], out);
        self.m.syms.record_size_equal(x, id);
        Ok(id)
    }

    /// Dynamic reshape: target extents read from `shape: s64[r]` at runtime.
    pub fn dreshape(&mut self, x: ValueId, shape: ValueId, out_rank: usize) -> Result<ValueId> {
        ensure!(self.ty(shape).dtype == DType::I64, "dreshape: shape tensor must be s64");
        let dtype = self.ty(x).dtype;
        let mut dims = Vec::with_capacity(out_rank);
        for axis in 0..out_rank {
            let s = self.m.syms.fresh(
                format!("drs{}_{axis}", self.m.instrs.len()),
                ShapeExpr::Elem { value: shape, index: axis },
            );
            dims.push(Dim::Sym(s));
        }
        let id = self.push(Op::DReshape, vec![x, shape], TensorType::new(dtype, dims));
        self.m.syms.record_size_equal(x, id);
        Ok(id)
    }

    // ---- shape-changing memory ops ------------------------------------------

    pub fn concat(&mut self, xs: &[ValueId], axis: usize) -> Result<ValueId> {
        ensure!(!xs.is_empty(), "concat: empty operand list");
        let t0 = self.ty(xs[0]).clone();
        ensure!(axis < t0.rank(), "concat: axis out of range");
        let mut axis_dims: Vec<Dim> = vec![t0.dims[axis]];
        let mut other = t0.dims.clone();
        for &x in &xs[1..] {
            let tx = self.ty(x).clone();
            ensure!(tx.dtype == t0.dtype && tx.rank() == t0.rank(), "concat: type mismatch");
            for a in 0..t0.rank() {
                if a != axis {
                    let merged = self.unify_shapes(&[other[a]], &[tx.dims[a]])?;
                    other[a] = merged[0];
                }
            }
            axis_dims.push(tx.dims[axis]);
        }
        let total: Option<usize> = axis_dims.iter().map(|d| d.fixed()).sum::<Option<usize>>();
        let cat_dim = match total {
            Some(n) => Dim::Fixed(n),
            None => {
                let expr = axis_dims
                    .iter()
                    .map(|&d| ShapeExpr::Dim(d))
                    .reduce(ShapeExpr::add)
                    .unwrap();
                Dim::Sym(self.m.syms.fresh(format!("cat{}", self.m.instrs.len()), expr))
            }
        };
        let mut dims = other;
        dims[axis] = cat_dim;
        Ok(self.push(Op::Concat { axis }, xs.to_vec(), TensorType::new(t0.dtype, dims)))
    }

    /// Static slice: HLO semantics, constant bounding box.
    pub fn slice(
        &mut self,
        x: ValueId,
        starts: Vec<i64>,
        limits: Vec<i64>,
        strides: Vec<i64>,
    ) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(
            starts.len() == tx.rank() && limits.len() == tx.rank() && strides.len() == tx.rank(),
            "slice: index rank mismatch"
        );
        let mut dims = Vec::with_capacity(tx.rank());
        for i in 0..tx.rank() {
            ensure!(strides[i] > 0 && starts[i] >= 0 && limits[i] >= starts[i], "slice: bad box");
            if let Some(n) = tx.dims[i].fixed() {
                ensure!(limits[i] as usize <= n, "slice: limit beyond dim {i}");
            }
            let extent = (limits[i] - starts[i] + strides[i] - 1) / strides[i];
            dims.push(Dim::Fixed(extent as usize));
        }
        Ok(self.push(
            Op::Slice { starts, limits, strides },
            vec![x],
            TensorType::new(tx.dtype, dims),
        ))
    }

    /// Dynamic slice (figure 2): the bounding box arrives as s64 tensors.
    /// Result dims are fresh symbols defined as
    /// `ceildiv(limit[i] - start[i], stride[i])` over runtime tensor reads.
    pub fn dslice(
        &mut self,
        x: ValueId,
        starts: ValueId,
        limits: ValueId,
        strides: ValueId,
    ) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        for &idx in &[starts, limits, strides] {
            ensure!(self.ty(idx).dtype == DType::I64, "dslice: indices must be s64");
            ensure!(
                self.ty(idx).dims == vec![Dim::Fixed(tx.rank())],
                "dslice: index tensors must be s64[rank]"
            );
        }
        let mut dims = Vec::with_capacity(tx.rank());
        for i in 0..tx.rank() {
            let expr = ShapeExpr::ceil_div(
                ShapeExpr::sub(
                    ShapeExpr::Elem { value: limits, index: i },
                    ShapeExpr::Elem { value: starts, index: i },
                ),
                ShapeExpr::Elem { value: strides, index: i },
            );
            dims.push(Dim::Sym(self.m.syms.fresh(format!("dsl{}_{i}", self.m.instrs.len()), expr)));
        }
        Ok(self.push(
            Op::DSlice,
            vec![x, starts, limits, strides],
            TensorType::new(tx.dtype, dims),
        ))
    }

    /// Static pad: `(x, pad_value)` with constant low/high widths.
    pub fn pad(
        &mut self,
        x: ValueId,
        value: ValueId,
        low: Vec<i64>,
        high: Vec<i64>,
    ) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(self.ty(value).rank() == 0, "pad: value must be scalar");
        ensure!(low.len() == tx.rank() && high.len() == tx.rank(), "pad: width rank mismatch");
        let mut dims = Vec::with_capacity(tx.rank());
        for i in 0..tx.rank() {
            ensure!(low[i] >= 0 && high[i] >= 0, "pad: negative width");
            let extra = (low[i] + high[i]) as usize;
            dims.push(match tx.dims[i] {
                Dim::Fixed(n) => Dim::Fixed(n + extra),
                Dim::Sym(s) if extra == 0 => Dim::Sym(s),
                Dim::Sym(s) => {
                    let expr = ShapeExpr::add(
                        ShapeExpr::Dim(Dim::Sym(s)),
                        ShapeExpr::Const(extra as i64),
                    );
                    Dim::Sym(self.m.syms.fresh(format!("pad{}_{i}", self.m.instrs.len()), expr))
                }
            });
        }
        Ok(self.push(Op::Pad { low, high }, vec![x, value], TensorType::new(tx.dtype, dims)))
    }

    /// Dynamic pad: widths arrive as s64 tensors.
    pub fn dpad(
        &mut self,
        x: ValueId,
        value: ValueId,
        low: ValueId,
        high: ValueId,
    ) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(self.ty(value).rank() == 0, "dpad: value must be scalar");
        let mut dims = Vec::with_capacity(tx.rank());
        for i in 0..tx.rank() {
            let expr = ShapeExpr::add(
                ShapeExpr::Dim(tx.dims[i]),
                ShapeExpr::add(
                    ShapeExpr::Elem { value: low, index: i },
                    ShapeExpr::Elem { value: high, index: i },
                ),
            );
            dims.push(Dim::Sym(self.m.syms.fresh(format!("dpd{}_{i}", self.m.instrs.len()), expr)));
        }
        Ok(self.push(Op::DPad, vec![x, value, low, high], TensorType::new(tx.dtype, dims)))
    }

    // ---- reductions / contractions ------------------------------------------

    pub fn reduce(&mut self, kind: ReduceKind, x: ValueId, axes: Vec<usize>) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        for &a in &axes {
            ensure!(a < tx.rank(), "reduce: axis out of range");
        }
        let dims: Vec<Dim> = tx
            .dims
            .iter()
            .enumerate()
            .filter(|(i, _)| !axes.contains(i))
            .map(|(_, &d)| d)
            .collect();
        Ok(self.push(Op::Reduce { kind, axes }, vec![x], TensorType::new(tx.dtype, dims)))
    }

    /// Matrix product. `[m,k]·[k,n]` or batched `[b,m,k]·[b,k,n]`; the
    /// contracting (and batch) dims are unified — another op-semantic
    /// constraint.
    pub fn dot(&mut self, a: ValueId, b: ValueId) -> Result<ValueId> {
        let (ta, tb) = (self.ty(a).clone(), self.ty(b).clone());
        ensure!(ta.dtype == DType::F32 && tb.dtype == DType::F32, "dot: f32 only");
        match (ta.rank(), tb.rank()) {
            (2, 2) => {
                let k = self.unify_shapes(&[ta.dims[1]], &[tb.dims[0]])?;
                let _ = k;
                let dims = vec![ta.dims[0], tb.dims[1]];
                Ok(self.push(Op::Dot, vec![a, b], TensorType::new(DType::F32, dims)))
            }
            (3, 3) => {
                let bdim = self.unify_shapes(&[ta.dims[0]], &[tb.dims[0]])?;
                let _ = self.unify_shapes(&[ta.dims[2]], &[tb.dims[1]])?;
                let dims = vec![bdim[0], ta.dims[1], tb.dims[2]];
                Ok(self.push(Op::Dot, vec![a, b], TensorType::new(DType::F32, dims)))
            }
            (ra, rb) => bail!("dot: unsupported ranks {ra}x{rb}"),
        }
    }

    // ---- gather / iota / unique ---------------------------------------------

    /// Take rows of `x` along `axis` at positions `idx: s64[m]`.
    pub fn gather(&mut self, x: ValueId, idx: ValueId, axis: usize) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        let ti = self.ty(idx).clone();
        ensure!(ti.dtype == DType::I64 && ti.rank() == 1, "gather: idx must be s64[m]");
        ensure!(axis < tx.rank(), "gather: axis out of range");
        let mut dims = tx.dims.clone();
        dims[axis] = ti.dims[0];
        Ok(self.push(Op::Gather { axis }, vec![x, idx], TensorType::new(tx.dtype, dims)))
    }

    pub fn iota(&mut self, dtype: DType, dims: Vec<Dim>, axis: usize) -> Result<ValueId> {
        ensure!(axis < dims.len().max(1), "iota: axis out of range");
        Ok(self.push(Op::Iota { axis }, vec![], TensorType::new(dtype, dims)))
    }

    /// `unique(x: s64[n]) → s64[u]`: `u` is data-dependent, modeled as a
    /// symbol whose value the executor fills after running the kernel.
    pub fn unique(&mut self, x: ValueId) -> Result<ValueId> {
        let tx = self.ty(x).clone();
        ensure!(tx.dtype == DType::I64 && tx.rank() == 1, "unique: wants s64[n]");
        let id = self.m.instrs.len();
        let s = self.m.syms.fresh(format!("uniq{id}"), ShapeExpr::DataDep { value: id });
        Ok(self.push(Op::Unique, vec![x], TensorType::new(DType::I64, vec![Dim::Sym(s)])))
    }

    pub fn get_dim_size(&mut self, x: ValueId, axis: usize) -> Result<ValueId> {
        ensure!(axis < self.ty(x).rank(), "get_dim_size: axis out of range");
        Ok(self.push(Op::GetDimSize { axis }, vec![x], TensorType::scalar(DType::I64)))
    }

    // ---- composites (bridge-level conveniences) -------------------------------

    /// Numerically-stable softmax over the last axis, expanded to primitives
    /// so the fusion planner sees the real memory-intensive op mix.
    pub fn softmax_last(&mut self, x: ValueId) -> Result<ValueId> {
        let rank = self.ty(x).rank();
        ensure!(rank >= 1, "softmax: rank >= 1");
        let last = rank - 1;
        let mx = self.reduce(ReduceKind::Max, x, vec![last])?;
        let mxb = self.broadcast_like_insert(mx, x, last)?;
        let centered = self.sub(x, mxb)?;
        let e = self.unary(UnKind::Exp, centered);
        let s = self.reduce(ReduceKind::Sum, e, vec![last])?;
        let sb = self.broadcast_like_insert(s, x, last)?;
        self.div(e, sb)
    }

    /// Layer norm over the last axis (mean/variance/normalize), expanded.
    pub fn layernorm_last(
        &mut self,
        x: ValueId,
        gamma: ValueId,
        beta: ValueId,
        eps: f32,
    ) -> Result<ValueId> {
        let rank = self.ty(x).rank();
        let last = rank - 1;
        let mean = self.reduce(ReduceKind::Mean, x, vec![last])?;
        let meanb = self.broadcast_like_insert(mean, x, last)?;
        let centered = self.sub(x, meanb)?;
        let sq = self.mul(centered, centered)?;
        let var = self.reduce(ReduceKind::Mean, sq, vec![last])?;
        let varb = self.broadcast_like_insert(var, x, last)?;
        let epsc = self.scalar_f32(eps);
        let epsb = self.broadcast_scalar_like(epsc, x)?;
        let denom_in = self.add(varb, epsb)?;
        let inv = self.unary(UnKind::Rsqrt, denom_in);
        let normed = self.mul(centered, inv)?;
        // gamma/beta are [hidden]; broadcast over leading axes.
        let gb = self.broadcast_row_like(gamma, x)?;
        let bb = self.broadcast_row_like(beta, x)?;
        let scaled = self.mul(normed, gb)?;
        self.add(scaled, bb)
    }

    /// Broadcast a reduced tensor back over the reduced axis `axis` of
    /// `like` (i.e. keepdims-style broadcast).
    pub fn broadcast_like_insert(
        &mut self,
        reduced: ValueId,
        like: ValueId,
        axis: usize,
    ) -> Result<ValueId> {
        let out = self.ty(like).dims.clone();
        let mapping: Vec<usize> = (0..out.len()).filter(|&a| a != axis).collect();
        self.broadcast(reduced, out, mapping)
    }

    /// Broadcast a `[hidden]` vector over the leading axes of `like`.
    pub fn broadcast_row_like(&mut self, row: ValueId, like: ValueId) -> Result<ValueId> {
        let out = self.ty(like).dims.clone();
        ensure!(self.ty(row).rank() == 1, "broadcast_row_like: wants rank-1");
        let mapping = vec![out.len() - 1];
        self.broadcast(row, out, mapping)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dyn_builder() -> (Builder, ValueId, ValueId, Dim) {
        let mut b = Builder::new("t");
        let seq = b.dyn_dim("seq", 0, 0);
        let x = b.param(DType::F32, vec![seq, Dim::Fixed(8)]);
        let seq2 = b.dyn_dim("seq2", 1, 0);
        let y = b.param(DType::F32, vec![seq2, Dim::Fixed(8)]);
        (b, x, y, seq)
    }

    #[test]
    fn binary_unifies_symbolic_dims() {
        let (mut b, x, y, seq) = dyn_builder();
        // Before the add, the two seq symbols are distinct.
        assert!(!b.m.same_shape(x, y));
        let z = b.add(x, y).unwrap();
        // Op semantics forced them equal (§4.2.1 first constraint source).
        assert!(b.m.same_shape(x, y));
        assert_eq!(b.m.syms.canon_dim(b.m.ty(z).dims[0]), b.m.syms.canon_dim(seq));
    }

    #[test]
    fn binary_rejects_fixed_mismatch() {
        let mut b = Builder::new("t");
        let x = b.param(DType::F32, vec![Dim::Fixed(2)]);
        let y = b.param(DType::F32, vec![Dim::Fixed(3)]);
        assert!(b.add(x, y).is_err());
    }

    #[test]
    fn fixed_refines_symbol() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let y = b.param(DType::F32, vec![Dim::Fixed(16)]);
        let z = b.add(x, y).unwrap();
        assert_eq!(b.m.syms.canon_dim(s), Dim::Fixed(16));
        // The merged result dim collapses to the constant.
        assert_eq!(b.m.ty(z).canon(&b.m.syms).dims[0], Dim::Fixed(16));
    }

    #[test]
    fn transpose_records_size_equality() {
        let (mut b, x, _, _) = dyn_builder();
        let t = b.transpose(x, vec![1, 0]).unwrap();
        assert!(b.m.same_size(x, t));
        assert_eq!(b.m.ty(t).dims[0], Dim::Fixed(8));
    }

    #[test]
    fn concat_dynamic_axis_is_sum() {
        let (mut b, x, y, _) = dyn_builder();
        let c = b.concat(&[x, y], 0).unwrap();
        let d = b.m.ty(c).dims[0];
        match d {
            Dim::Sym(s) => {
                let def = b.m.syms.def(s).to_string();
                assert!(def.contains('+'), "expected sum expr, got {def}");
            }
            Dim::Fixed(_) => panic!("expected symbolic concat dim"),
        }
        assert_eq!(b.m.ty(c).dims[1], Dim::Fixed(8));
    }

    #[test]
    fn dslice_mints_ceildiv_symbols() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let st = b.i64_vec(&[0, 0]);
        let li = b.i64_vec(&[2, 4]);
        let sr = b.i64_vec(&[1, 1]);
        let sl = b.dslice(x, st, li, sr).unwrap();
        for d in &b.m.ty(sl).dims {
            match d {
                Dim::Sym(sy) => {
                    assert!(b.m.syms.def(*sy).to_string().contains("ceildiv"));
                }
                _ => panic!("dslice dims should be symbolic"),
            }
        }
    }

    #[test]
    fn dot_shapes_and_contract_unification() {
        let mut b = Builder::new("t");
        let m = b.dyn_dim("m", 0, 0);
        let a = b.param(DType::F32, vec![m, Dim::Fixed(64)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(64), Dim::Fixed(32)]);
        let d = b.dot(a, w).unwrap();
        assert_eq!(b.m.ty(d).dims[1], Dim::Fixed(32));
        assert!(b.m.ty(d).dims[0].is_dynamic());
        assert!(b.m.op(d).is_compute_intensive());
    }

    #[test]
    fn reduce_drops_axes() {
        let (mut b, x, _, _) = dyn_builder();
        let r = b.reduce(ReduceKind::Sum, x, vec![1]).unwrap();
        assert_eq!(b.m.ty(r).rank(), 1);
        assert!(b.m.ty(r).dims[0].is_dynamic());
    }

    #[test]
    fn unique_is_data_dependent() {
        let mut b = Builder::new("t");
        let n = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::I64, vec![n]);
        let u = b.unique(x).unwrap();
        match b.m.ty(u).dims[0] {
            Dim::Sym(s) => assert!(matches!(b.m.syms.def(s), ShapeExpr::DataDep { .. })),
            _ => panic!(),
        }
    }

    #[test]
    fn softmax_expansion_op_mix() {
        let (mut b, x, _, _) = dyn_builder();
        let y = b.softmax_last(x).unwrap();
        let m = b.finish(vec![y]);
        // max, 2 broadcasts, sub, exp, sum, div = 7 new memory-intensive ops.
        let kinds: Vec<String> = m.instrs.iter().map(|i| i.op.name()).collect();
        assert!(kinds.iter().any(|k| k == "reduce.max"));
        assert!(kinds.iter().any(|k| k == "exponential"));
        assert!(kinds.iter().any(|k| k == "divide"));
        assert!(m.same_shape(m.outputs[0], 0));
    }

    #[test]
    fn layernorm_expansion_shapes() {
        let (mut b, x, _, _) = dyn_builder();
        let g = b.param(DType::F32, vec![Dim::Fixed(8)]);
        let be = b.param(DType::F32, vec![Dim::Fixed(8)]);
        let y = b.layernorm_last(x, g, be, 1e-5).unwrap();
        assert!(b.m.same_shape(y, x));
    }

    #[test]
    fn fully_static_detection() {
        let mut b = Builder::new("t");
        let x = b.param(DType::F32, vec![Dim::Fixed(4), Dim::Fixed(4)]);
        let y = b.unary(UnKind::Tanh, x);
        let m = b.finish(vec![y]);
        assert!(m.is_fully_static());

        let (mut b2, x2, _, _) = dyn_builder();
        let y2 = b2.unary(UnKind::Tanh, x2);
        let m2 = b2.finish(vec![y2]);
        assert!(!m2.is_fully_static());
    }
}
