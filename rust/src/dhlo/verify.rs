//! Structural verifier for DHLO modules.
//!
//! The builder already enforces per-op typing; the verifier re-checks global
//! invariants that passes could break: SSA dominance (operands precede
//! users), output validity, parameter indexing, shape-operand typing of the
//! dynamic twins, and rank agreement between an instruction's recorded type
//! and its op's expectations.

use super::module::Module;
use super::op::Op;
use super::types::DType;
use anyhow::{bail, ensure, Result};

/// Verify a module, returning the first violated invariant as an error.
pub fn verify(m: &Module) -> Result<()> {
    let n = m.instrs.len();
    let mut param_seen = vec![false; m.params.len()];

    for (id, ins) in m.instrs.iter().enumerate() {
        // SSA: operands must be defined earlier (topological order).
        for &o in &ins.operands {
            ensure!(o < id, "instr %{id}: operand %{o} does not dominate it");
        }

        match &ins.op {
            Op::Param { index } => {
                ensure!(*index < m.params.len(), "%{id}: parameter index {index} out of range");
                ensure!(!param_seen[*index], "%{id}: duplicate parameter {index}");
                ensure!(
                    m.params[*index] == ins.ty,
                    "%{id}: parameter type {} disagrees with signature {}",
                    ins.ty,
                    m.params[*index]
                );
                param_seen[*index] = true;
                ensure!(ins.operands.is_empty(), "%{id}: parameter takes no operands");
            }
            Op::Const { lit, dims } => {
                let want: usize = dims.iter().product::<usize>().max(1);
                ensure!(lit.len() == want, "%{id}: constant literal length mismatch");
                ensure!(ins.ty.is_static(), "%{id}: constants must be static");
            }
            Op::Un(_) => ensure!(ins.operands.len() == 1, "%{id}: unary arity"),
            Op::Bin(_) | Op::Cmp(_) => {
                ensure!(ins.operands.len() == 2, "%{id}: binary arity");
                let (a, b) = (ins.operands[0], ins.operands[1]);
                ensure!(
                    m.ty(a).rank() == m.ty(b).rank() && m.ty(a).rank() == ins.ty.rank(),
                    "%{id}: elementwise rank mismatch"
                );
                if matches!(ins.op, Op::Cmp(_)) {
                    ensure!(ins.ty.dtype == DType::Pred, "%{id}: compare must produce pred");
                }
            }
            Op::Select => {
                ensure!(ins.operands.len() == 3, "%{id}: select arity");
                ensure!(
                    m.ty(ins.operands[0]).dtype == DType::Pred,
                    "%{id}: select predicate must be pred"
                );
            }
            Op::Convert(t) => {
                ensure!(ins.operands.len() == 1, "%{id}: convert arity");
                ensure!(ins.ty.dtype == *t, "%{id}: convert type mismatch");
            }
            Op::Broadcast { dims } => {
                ensure!(ins.operands.len() == 1, "%{id}: broadcast arity");
                let xin = m.ty(ins.operands[0]);
                ensure!(dims.len() == xin.rank(), "%{id}: broadcast mapping rank");
                for &d in dims {
                    ensure!(d < ins.ty.rank(), "%{id}: broadcast mapping out of range");
                }
            }
            Op::DBroadcast { .. } | Op::DReshape => {
                ensure!(ins.operands.len() == 2, "%{id}: dynamic-twin arity");
                ensure!(
                    m.ty(ins.operands[1]).dtype == DType::I64,
                    "%{id}: shape operand must be s64"
                );
            }
            Op::Transpose { perm } => {
                ensure!(ins.operands.len() == 1, "%{id}: transpose arity");
                ensure!(
                    perm.len() == m.ty(ins.operands[0]).rank(),
                    "%{id}: transpose perm rank"
                );
            }
            Op::Reshape => ensure!(ins.operands.len() == 1, "%{id}: reshape arity"),
            Op::Concat { axis } => {
                ensure!(!ins.operands.is_empty(), "%{id}: concat needs operands");
                ensure!(*axis < ins.ty.rank(), "%{id}: concat axis");
            }
            Op::Slice { starts, limits, strides } => {
                let r = m.ty(ins.operands[0]).rank();
                ensure!(
                    starts.len() == r && limits.len() == r && strides.len() == r,
                    "%{id}: slice attr rank"
                );
            }
            Op::DSlice => {
                ensure!(ins.operands.len() == 4, "%{id}: dslice arity");
                for &slot in &[1usize, 2, 3] {
                    ensure!(
                        m.ty(ins.operands[slot]).dtype == DType::I64,
                        "%{id}: dslice index operand {slot} must be s64"
                    );
                }
            }
            Op::Pad { low, high } => {
                ensure!(ins.operands.len() == 2, "%{id}: pad arity");
                let r = m.ty(ins.operands[0]).rank();
                ensure!(low.len() == r && high.len() == r, "%{id}: pad widths rank");
            }
            Op::DPad => {
                ensure!(ins.operands.len() == 4, "%{id}: dpad arity");
                ensure!(
                    m.ty(ins.operands[2]).dtype == DType::I64
                        && m.ty(ins.operands[3]).dtype == DType::I64,
                    "%{id}: dpad widths must be s64"
                );
            }
            Op::Reduce { axes, .. } => {
                let r = m.ty(ins.operands[0]).rank();
                for &a in axes {
                    ensure!(a < r, "%{id}: reduce axis out of range");
                }
                ensure!(ins.ty.rank() == r - axes.len(), "%{id}: reduce output rank");
            }
            Op::Dot => {
                ensure!(ins.operands.len() == 2, "%{id}: dot arity");
                let (ra, rb) = (m.ty(ins.operands[0]).rank(), m.ty(ins.operands[1]).rank());
                ensure!(
                    (ra == 2 && rb == 2) || (ra == 3 && rb == 3),
                    "%{id}: dot rank {ra}x{rb}"
                );
            }
            Op::Gather { axis } => {
                ensure!(ins.operands.len() == 2, "%{id}: gather arity");
                ensure!(*axis < m.ty(ins.operands[0]).rank(), "%{id}: gather axis");
                ensure!(
                    m.ty(ins.operands[1]).dtype == DType::I64,
                    "%{id}: gather indices must be s64"
                );
            }
            Op::Iota { axis } => {
                ensure!(*axis < ins.ty.rank().max(1), "%{id}: iota axis");
            }
            Op::Unique => {
                ensure!(ins.operands.len() == 1, "%{id}: unique arity");
                ensure!(
                    m.ty(ins.operands[0]).dtype == DType::I64,
                    "%{id}: unique wants s64 input"
                );
            }
            Op::GetDimSize { axis } => {
                ensure!(*axis < m.ty(ins.operands[0]).rank(), "%{id}: get_dim_size axis");
                ensure!(ins.ty.rank() == 0, "%{id}: get_dim_size must be scalar");
            }
        }
    }

    for &o in &m.outputs {
        if o >= n {
            bail!("output %{o} out of range");
        }
    }
    ensure!(!m.outputs.is_empty(), "module has no outputs");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::shape::Dim;

    #[test]
    fn accepts_wellformed() {
        let mut b = Builder::new("ok");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let y = b.unary(UnKind::Exp, x);
        let m = b.finish(vec![y]);
        verify(&m).unwrap();
    }

    #[test]
    fn rejects_bad_output() {
        let mut b = Builder::new("bad");
        let x = b.param(DType::F32, vec![Dim::Fixed(2)]);
        let mut m = b.finish(vec![x]);
        m.outputs = vec![99];
        assert!(verify(&m).is_err());
    }

    #[test]
    fn rejects_forward_reference() {
        let mut b = Builder::new("bad");
        let x = b.param(DType::F32, vec![Dim::Fixed(2)]);
        let y = b.unary(UnKind::Exp, x);
        let mut m = b.finish(vec![y]);
        // Corrupt: make the unary reference a later id.
        m.instrs[1].operands[0] = 1;
        assert!(verify(&m).is_err());
    }

    #[test]
    fn rejects_empty_outputs() {
        let mut b = Builder::new("bad");
        let _ = b.param(DType::F32, vec![Dim::Fixed(2)]);
        let m = b.finish(vec![]);
        assert!(verify(&m).is_err());
    }
}
