//! Runtime substrate: host tensors, symbol resolution, the reference
//! interpreter (numerics oracle + eager baseline), buffer management, the
//! PJRT device wrapper, and the compiled-program executor.

pub mod artifacts;
pub mod batching;
pub mod buffers;
pub mod eager;
pub mod executor;
pub mod faults;
pub mod kv;
pub mod memplan;
pub mod metrics;
pub mod pjrt;
pub mod plan;
pub mod reference;
pub mod shape_env;
pub mod tensor;

pub use shape_env::SymEnv;
pub use tensor::Tensor;
