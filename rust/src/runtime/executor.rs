//! The program executor: walks the compile-time-generated runtime flow.
//!
//! Per request: bind input shapes (checking constraints), then execute the
//! flat step array — host ops on the host, fused kernels through the
//! bucket-keyed executable cache, GEMMs through the library, deallocations
//! where liveness placed them. No graph interpretation happens here; this
//! is the "generated runtime flow works more efficiently" half of the
//! paper's Table 2 CPU-time comparison (the other half is `crate::vm`).
//!
//! Three execution tiers (see docs/runtime.md):
//!
//! 1. **Interpret** — resolve symbolic dims per step, hash cache keys,
//!    decide pad/crop, marshal host tensors per launch. Always correct;
//!    used for the first request of a binding vector and as the fallback.
//! 2. **Record** — tier 1 plus a [`PlanRecorder`]: the resolved flow is
//!    captured as a [`LaunchPlan`] keyed by the binding vector.
//! 3. **Replay** — repeat bindings skip resolution, hashing, and
//!    branching entirely, and chain fused-kernel/GEMM results through
//!    persistent device buffers: GEMMs consume device-resident operands
//!    dev→dev (bucket adaptation happens on device), static GEMM weights
//!    are served from the library's persistent weight cache (uploaded once
//!    per program, pinned by installed plans), and only program outputs
//!    and host-op operands are copied back to the host.
//!
//! Batched dispatches run the same three tiers at *group* granularity —
//! see `runtime::batching` for the stacked walk and its
//! `BatchPlan` record/replay (`batch_plans` here mirrors `plans`, with
//! the same FIFO bound and weight-pin discipline).

use crate::codegen::policy::{derive_boundaries, PolicySwitch};
use crate::codegen::{BucketPolicy, KernelCache};
use crate::dhlo::{DType, Module, Op, ValueId};
use crate::library::{GemmLibrary, GemmSrc, WeightKey};
use crate::program::{Program, Step};
use crate::runtime::buffers::BufferPool;
use crate::runtime::kv::{DecodeSpec, KvCache};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::pjrt::{Device, DeviceTensor};
use crate::runtime::plan::{
    binding_vector, host_guards_hold, BatchPlan, BatchPlanKey, LaunchPlan, PlanKey, PlanRecorder,
    PlanStats, PlanWeight, PlannedStep,
};
use crate::runtime::reference::eval_op;
use crate::runtime::shape_env::SymEnv;
use crate::runtime::tensor::{strides_of, Data, Tensor};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

/// Runtime behavior toggles shared by `ExecOptions` and
/// `CompileOptions`: one definition, embedded by both, so a flag added
/// here reaches the CLI, the compiler driver, and every forked worker
/// without being duplicated field-by-field.
#[derive(Debug, Clone)]
pub struct RuntimeOptions {
    /// Serve static GEMM RHS operands (graph constants, entry parameters)
    /// from the library's persistent device-side weight cache: each weight
    /// uploads once per program and is reused across calls and plan
    /// replays. Requires `device_resident`.
    pub weight_cache: bool,
    /// Speculative neighbor-bucket warming: when a request *records* a new
    /// plan, enqueue background compiles for the next bucket of every
    /// dynamic symbol it touched (the bucket a growing sequence length
    /// lands in next), so that traffic arriving there finds the kernel
    /// resident and stalls zero. Off by default: it trades background
    /// compile work for tail latency, which is a serving-process decision
    /// (`disc run --warm` turns it on).
    pub speculative_warm: bool,
    /// Symbolic memory planning (`runtime/memplan.rs`): plan installs
    /// carry an instantiated `MemoryPlan` and replays acquire one planned
    /// extent instead of a block per intermediate. On by default;
    /// `disc run --no-memplan` (and the ablation row) turn it off.
    pub memory_plan: bool,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        RuntimeOptions { weight_cache: true, speculative_warm: false, memory_plan: true }
    }
}

impl RuntimeOptions {
    pub fn with_weight_cache(mut self, on: bool) -> Self {
        self.weight_cache = on;
        self
    }

    pub fn with_speculative_warm(mut self, on: bool) -> Self {
        self.speculative_warm = on;
        self
    }

    pub fn with_memory_plan(mut self, on: bool) -> Self {
        self.memory_plan = on;
        self
    }
}

/// Executor options.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    pub policy: BucketPolicy,
    /// Use the pooled (cached) allocator for marshalling buffers.
    pub pooled_buffers: bool,
    /// Cache resolved launch plans per symbol-binding vector and replay
    /// them on repeat shapes.
    pub plan_cache: bool,
    /// During replays, keep fused-kernel and GEMM results device-resident
    /// between launches instead of round-tripping through host tensors.
    pub device_resident: bool,
    /// Shared runtime toggles (weight cache, speculative warming, memory
    /// planning) — the same struct `CompileOptions` embeds.
    pub runtime: RuntimeOptions,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            policy: BucketPolicy::NextPow2,
            pooled_buffers: true,
            plan_cache: true,
            device_resident: true,
            runtime: RuntimeOptions::default(),
        }
    }
}

/// A device-resident intermediate: the bucket-shaped buffer plus the
/// actual extents a host consumer would crop to. `zero_padded` records
/// whether the pad lanes are exact zeros (GEMM results) or garbage
/// (fused-kernel outputs) — the library's device-side GEMM path consumes
/// zero-padded buffers in place and routes the rest through its on-device
/// bucket adapter. (Also the joint-lane slot of batched plan replays; see
/// `runtime::batching`.)
pub(crate) struct DevSlot {
    pub(crate) dt: DeviceTensor,
    pub(crate) actual: Vec<usize>,
    pub(crate) zero_padded: bool,
    /// Per-buffer arena lease (planner-off replay). `None` when the replay
    /// holds one planned extent for every slot instead.
    pub(crate) lease: Option<crate::runtime::buffers::ArenaLease>,
}

/// Is this value a cacheable GEMM weight? Graph constants never change for
/// a given program; entry parameters can carry new contents at a fixed
/// shape, so their cache entries are fingerprint-validated per call.
pub(crate) fn weight_ref_of(m: &Module, value: ValueId) -> Option<PlanWeight> {
    match &m.instrs[value].op {
        Op::Const { .. } => Some(PlanWeight { value, validate: false }),
        Op::Param { .. } => Some(PlanWeight { value, validate: true }),
        _ => None,
    }
}

/// Stateful executor: owns the kernel cache, library, buffer pool, and the
/// launch-plan cache, so all of them persist across requests (that is the
/// whole point).
pub struct Executor {
    pub cache: KernelCache,
    pub library: GemmLibrary,
    pub pool: BufferPool,
    pub opts: ExecOptions,
    pub device: Arc<Device>,
    plans: HashMap<PlanKey, Arc<LaunchPlan>>,
    /// Insertion order of `plans`, for FIFO eviction at `max_plans`.
    plan_order: std::collections::VecDeque<PlanKey>,
    /// Weight pins each installed plan actually took (a pin attempt on an
    /// already-evicted entry takes none); eviction releases exactly these,
    /// so a failed pin can never steal another plan's.
    plan_pins: HashMap<PlanKey, Vec<WeightKey>>,
    /// Bound on cached plans: binding vectors are exact (not bucketed), so
    /// a long-lived server over adversarial shape streams would otherwise
    /// grow host+device pinning without limit.
    pub max_plans: usize,
    pub plan_stats: PlanStats,
    /// Cached cross-request batchability analyses, per program id (see
    /// `runtime::batching`). Seeded at compile time by `DiscCompiler` and
    /// shared across forked workers, so serving never re-derives the
    /// Stacked/Shared/PerRequest classification.
    pub(crate) batch_info: HashMap<u64, Arc<crate::runtime::batching::BatchAnalysis>>,
    /// How many batchability analyses THIS executor computed itself (0
    /// when every program was seeded at compile time; tests assert repeat
    /// dispatches never re-analyze).
    pub batch_analyses: u64,
    /// Recorded batched walks, keyed by group shape (residual bindings +
    /// sorted member extents); same FIFO bound and weight-pin discipline
    /// as the solo plan cache.
    pub(crate) batch_plans: HashMap<BatchPlanKey, Arc<BatchPlan>>,
    pub(crate) batch_plan_order: std::collections::VecDeque<BatchPlanKey>,
    pub(crate) batch_plan_pins: HashMap<BatchPlanKey, Vec<WeightKey>>,
    pub batch_plan_stats: PlanStats,
    /// Compile-time symbolic memory plans, per program id: built once
    /// (seeded by `DiscCompiler`, shared across forked workers like
    /// `batch_info`) and instantiated per binding at plan-install time.
    pub(crate) mem_plans: HashMap<u64, Arc<crate::runtime::memplan::MemoryPlan>>,
    /// The traffic-adaptive bucket-policy switch: shared (like the kernel
    /// store) across every worker forked from this executor, so the extent
    /// histogram aggregates all traffic and a boundary swap is observed by
    /// the whole pool. `opts.policy` stays the base (epoch-0) policy.
    pub switch: Arc<PolicySwitch>,
}

pub struct ExecOutput {
    pub outputs: Vec<Tensor>,
    pub metrics: RunMetrics,
}

/// Result of one request's decode loop (`Executor::run_decode`).
pub struct DecodeOutput {
    /// Argmax-sampled token ids, one per generation step.
    pub generated: Vec<i64>,
    /// The `[1, vocab]` probability row of every step, prompt included.
    pub step_probs: Vec<Tensor>,
    /// Total steps executed (prompt + generated).
    pub steps: usize,
    pub metrics: RunMetrics,
}

/// Point-in-time copy of the executor's component stats, taken at run
/// start and folded into that run's `RunMetrics` deltas by `fold_stats`.
pub(crate) struct StatSnapshot {
    pub(crate) lib: crate::library::LibraryStats,
    pub(crate) cache: crate::codegen::CacheStats,
    pub(crate) pool: crate::runtime::buffers::PoolStats,
}

/// Compile-time proof that an executor can be moved into a worker thread
/// (the multi-worker coordinator does exactly that): everything it holds
/// across requests is owned or `Arc`-shared thread-safe state. Transient
/// `Rc<Tensor>` value stores live only inside a single `run` call.
const _: fn() = || {
    fn ok<T: Send>() {}
    ok::<Executor>();
};

impl Drop for Executor {
    fn drop(&mut self) {
        // The executor's plans die with it; give their weight pins back to
        // the shared store (see `release_all_pins`).
        self.release_all_pins();
    }
}

impl Executor {
    /// Standalone executor over private stores (tests, single-model CLI
    /// runs). Cache and library still share one kernel store, so fused
    /// kernels and GEMM entries live in the same table.
    pub fn new(device: Arc<Device>, opts: ExecOptions) -> Self {
        let store = Arc::new(crate::codegen::KernelStore::new(device.clone()));
        Self::with_shared(device, opts, store, Arc::new(crate::library::WeightStore::new()))
    }

    /// A worker executor over process-shared stores: the kernel store and
    /// weight store are shared with every other worker (compile-once,
    /// upload-once across the process); the plan cache, buffer pool, and
    /// stats stay per-worker.
    pub fn with_shared(
        device: Arc<Device>,
        opts: ExecOptions,
        store: Arc<crate::codegen::KernelStore>,
        weights: Arc<crate::library::WeightStore>,
    ) -> Self {
        let switch = Arc::new(PolicySwitch::new(opts.policy));
        let mut cache = KernelCache::with_store(store.clone(), opts.policy);
        cache.set_switch(switch.clone());
        Executor {
            cache,
            library: GemmLibrary::with_shared(device.clone(), store, weights),
            pool: BufferPool::new(),
            opts,
            device,
            plans: HashMap::new(),
            plan_order: std::collections::VecDeque::new(),
            plan_pins: HashMap::new(),
            max_plans: 512,
            plan_stats: PlanStats::default(),
            batch_info: HashMap::new(),
            batch_analyses: 0,
            batch_plans: HashMap::new(),
            batch_plan_order: std::collections::VecDeque::new(),
            batch_plan_pins: HashMap::new(),
            batch_plan_stats: PlanStats::default(),
            mem_plans: HashMap::new(),
            switch,
        }
    }

    /// Release every weight pin this executor's installed plans hold. The
    /// weight store is process-shared and outlives forked workers, so pins
    /// must die with the plans that took them — otherwise a long-running
    /// server forking workers per serve call would accumulate unevictable
    /// entries past any byte budget.
    fn release_all_pins(&mut self) {
        for (_, pins) in self.plan_pins.drain() {
            for wk in pins {
                self.library.unpin_weight(&wk);
            }
        }
        for (_, pins) in self.batch_plan_pins.drain() {
            for wk in pins {
                self.library.unpin_weight(&wk);
            }
        }
    }

    /// Fork a sibling worker: same device, same shared kernel/weight
    /// stores, same options, plan-cache bound, and (compile-time-seeded)
    /// batchability analyses — fresh plan caches, pools, and stats. This
    /// is how the multi-worker coordinator builds its workers.
    pub fn fork(&self) -> Executor {
        let mut e = Self::with_shared(
            self.device.clone(),
            self.opts.clone(),
            self.cache.store().clone(),
            self.library.weight_store().clone(),
        );
        e.max_plans = self.max_plans;
        e.batch_info = self.batch_info.clone();
        e.mem_plans = self.mem_plans.clone();
        // One policy switch per worker pool: forks share the parent's, so
        // the histogram aggregates and epoch flips reach every worker.
        e.switch = self.switch.clone();
        e.cache.set_switch(e.switch.clone());
        e
    }

    /// Install a precomputed batchability analysis for a program (computed
    /// once at compile time by `DiscCompiler` and shared, via `fork`, by
    /// every worker serving the model).
    pub fn seed_batch_analysis(
        &mut self,
        program: u64,
        analysis: Arc<crate::runtime::batching::BatchAnalysis>,
    ) {
        self.batch_info.insert(program, analysis);
    }

    /// Install a compile-time symbolic memory plan for a program (built
    /// once by `DiscCompiler`, shared across forked workers).
    pub fn seed_memory_plan(
        &mut self,
        program: u64,
        plan: Arc<crate::runtime::memplan::MemoryPlan>,
    ) {
        self.mem_plans.insert(program, plan);
    }

    /// One re-bucketing cycle for `prog`: derive candidate boundaries from
    /// the shared traffic histogram, pre-compile the candidate bucket
    /// family for every recorded launch site through the background
    /// compile pool, wait for those compiles to land, then flip the epoch.
    /// Dispatches never stall on the swap — by the time the epoch moves,
    /// the whole new family is resident in the shared store. Returns
    /// `true` when a new epoch was installed (`false`: no traffic yet, or
    /// the derived cuts already match the live ones).
    ///
    /// The coordinator's re-bucketing loop calls this on a dedicated
    /// forked worker so histogram snapshots, spec emission, and the
    /// quiesce wait all happen off the serving hot path.
    pub fn rebucket(&mut self, prog: &Program, max_cuts: usize) -> Result<bool> {
        let snap = self.switch.histogram.snapshot();
        if snap.total == 0 {
            return Ok(false);
        }
        let cand = derive_boundaries(&snap, max_cuts.max(1), self.switch.base());
        if cand.is_trivial() {
            return Ok(false);
        }
        let (_, cur) = self.switch.snapshot();
        if cur.cuts == cand.cuts {
            return Ok(false);
        }
        for ((pid, fi), actuals) in &snap.sites {
            if *pid != prog.id {
                continue;
            }
            let Some(fl) = prog.fused.get(*fi) else { continue };
            for actual in actuals {
                self.cache.prefetch_bucketed(
                    &prog.module,
                    &fl.group,
                    &fl.sig,
                    &fl.syms,
                    actual,
                    &cand,
                )?;
            }
        }
        // Zero-stall swap: the epoch flips only after the candidate family
        // finished compiling.
        self.cache.store().quiesce();
        self.switch.install(cand);
        Ok(true)
    }

    /// The program's symbolic memory plan, building it on first use when
    /// the compiler did not seed one (standalone executors in tests).
    pub(crate) fn mem_plan_for(
        &mut self,
        prog: &Program,
    ) -> Arc<crate::runtime::memplan::MemoryPlan> {
        let policy = self.opts.policy;
        self.mem_plans
            .entry(prog.id)
            .or_insert_with(|| {
                Arc::new(crate::runtime::memplan::MemoryPlan::build(prog, policy))
            })
            .clone()
    }

    /// Component-stat snapshot taken at the start of a run, so the
    /// lifetime counters can be folded into per-run `RunMetrics` deltas.
    pub(crate) fn stats_snapshot(&self) -> StatSnapshot {
        StatSnapshot {
            lib: self.library.stats.clone(),
            cache: self.cache.stats.clone(),
            pool: self.pool.stats.clone(),
        }
    }

    /// Fold component-level stat deltas since `before` into `metrics`
    /// (shared by `run` and the batched dispatch path).
    pub(crate) fn fold_stats(&self, metrics: &mut RunMetrics, before: &StatSnapshot) {
        metrics.flops = self.library.stats.flops - before.lib.flops;
        metrics.compile_events = self.cache.stats.misses - before.cache.misses;
        metrics.compile_time += self.cache.stats.compile_time - before.cache.compile_time;
        // Compile-service interaction: time this run blocked on the
        // background compiler (fused kernels via the cache handle, GEMM and
        // prepare builds via the library handle) and in-flight compiles it
        // joined instead of duplicating (the store's single-flight dedup).
        metrics.compile_stall += self.cache.stats.stall - before.cache.stall;
        metrics.compile_stall += self.library.stats.build_stall - before.lib.build_stall;
        metrics.compile_dedup_hits = (self.cache.stats.dedup_hits - before.cache.dedup_hits)
            + (self.library.stats.build_dedup_hits - before.lib.build_dedup_hits);
        metrics.allocs = self.pool.stats.allocs - before.pool.allocs;
        metrics.pool_hits = self.pool.stats.pool_hits - before.pool.pool_hits;
        // Library transfer traffic is accounted where it happens
        // (LibraryStats) and folded in per run, so benches and RunMetrics
        // agree; the weight cache shows up as hit/miss counts plus the
        // resident-bytes gauge.
        metrics.h2d_bytes += self.library.stats.h2d_bytes - before.lib.h2d_bytes;
        metrics.d2h_bytes += self.library.stats.d2h_bytes - before.lib.d2h_bytes;
        metrics.weight_cache_hits = self.library.stats.weight_hits - before.lib.weight_hits;
        metrics.weight_cache_misses =
            self.library.stats.weight_misses - before.lib.weight_misses;
        metrics.weight_resident_bytes = self.library.weight_resident_bytes();
    }

    /// Execute a program against concrete inputs, descending the
    /// degradation ladder on faults (see docs/runtime.md §Failure model):
    ///
    /// 1. the tiered path (replay → interpret) — a replay that *errors*
    ///    (transfer fault, simulated OOM) demotes this request to the
    ///    interpret tier, counted in `RunMetrics::demotions`;
    /// 2. a tiered attempt that fails on a *compile* error is retried with
    ///    capped exponential backoff (`RunMetrics::retries`) — the failed
    ///    single-flight slot was dropped, so a retry re-issues the compile;
    /// 3. anything still failing falls to the host reference interpreter
    ///    (`runtime::reference::eval_module`), the always-correct bottom
    ///    rung that touches neither device nor compiler.
    ///
    /// Fault-free requests take exactly the old path: one branch per rung.
    pub fn run(&mut self, prog: &Program, inputs: &[Tensor]) -> Result<ExecOutput> {
        const MAX_COMPILE_RETRIES: u32 = 3;
        let t_start = Instant::now();
        let mut retries = 0u32;
        let mut backoff = std::time::Duration::from_millis(1);
        let last_err = loop {
            match self.run_tiered(prog, inputs) {
                Ok(mut out) => {
                    out.metrics.retries += retries as u64;
                    return Ok(out);
                }
                Err(e) => {
                    let chain = format!("{e:#}");
                    if chain.contains("compile") && retries < MAX_COMPILE_RETRIES {
                        retries += 1;
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(std::time::Duration::from_millis(8));
                        continue;
                    }
                    break e;
                }
            }
        };
        // Bottom rung: serve the request from the host reference
        // interpreter. Slower, but it answers — the coordinator's
        // zero-lost-requests guarantee rests on this.
        match crate::runtime::reference::eval_module(&prog.module, inputs) {
            Ok(r) => {
                let metrics = RunMetrics {
                    mem_kernels: r.launches as u64,
                    mem_bytes: r.bytes_moved as u64,
                    retries: retries as u64,
                    demotions: 1,
                    total_time: t_start.elapsed(),
                    ..Default::default()
                };
                Ok(ExecOutput { outputs: r.outputs, metrics })
            }
            // The reference path failed too (malformed request): report the
            // ladder's original error, which names the faulted seam.
            Err(_) => Err(last_err),
        }
    }

    /// Drive one request's autoregressive decode loop: feed the prompt,
    /// then `gen_steps` argmax-sampled tokens, one `run` per step over the
    /// request's [`KvCache`]. Every step inside a bucket binds the slab at
    /// the same padded capacity, so the whole bucket replays one
    /// `LaunchPlan` family; a rollover re-records exactly once (one
    /// `plan_misses` tick per bucket).
    ///
    /// Slab residency: the request's slab bytes are acquired in the
    /// arena's KV class up front and re-accounted at each rollover. An
    /// injected OOM on acquisition *demotes* the request to host-resident
    /// slabs (counted in `demotions`) instead of failing it — the compute
    /// path is identical, only the residency accounting is lost, matching
    /// the serving stack's degrade-don't-drop discipline. All exit paths,
    /// error included, release whatever the request holds.
    pub fn run_decode(
        &mut self,
        prog: &Program,
        spec: &DecodeSpec,
        prompt: &[i64],
        gen_steps: usize,
    ) -> Result<DecodeOutput> {
        anyhow::ensure!(!prompt.is_empty(), "decode needs at least one prompt token");
        let mut kv = KvCache::new(*spec, self.opts.policy).with_switch(self.switch.clone());
        let faults = self.device.faults().cloned();
        let mut metrics = RunMetrics { decode_requests: 1, ..Default::default() };
        // The slab is planner-owned as a long-lived KV-class slot: one
        // lease per bucket, re-planned only at rollover. Drop = release.
        let mut slab = self
            .pool
            .device
            .acquire(
                crate::runtime::buffers::ResidencyClass::Kv,
                kv.slab_bytes(),
                faults.as_deref(),
            )
            .ok();
        if slab.is_none() {
            metrics.demotions += 1;
        }

        let total = prompt.len() + gen_steps;
        let mut generated = Vec::with_capacity(gen_steps);
        let mut step_probs = Vec::with_capacity(total);
        let mut result = Ok(());
        for step in 0..total {
            if kv.full() {
                // Bucket rollover: the next step binds a new capacity (one
                // fresh plan record); re-plan the slab slot at its new size.
                kv.grow();
                metrics.kv_rollovers += 1;
                if slab.is_some() {
                    drop(slab.take()); // release the old bucket's lease first
                    slab = self
                        .pool
                        .device
                        .acquire(
                            crate::runtime::buffers::ResidencyClass::Kv,
                            kv.slab_bytes(),
                            faults.as_deref(),
                        )
                        .ok();
                    if slab.is_none() {
                        metrics.demotions += 1;
                    }
                }
            }
            let token = if step < prompt.len() {
                prompt[step]
            } else {
                let t = argmax_token(step_probs.last().expect("probs of previous step"));
                generated.push(t);
                t
            };
            result = (|| {
                let inputs = kv.step_inputs(token)?;
                let out = self.run(prog, &inputs)?;
                metrics += &out.metrics;
                metrics.decode_steps += 1;
                let mut outs = out.outputs;
                anyhow::ensure!(
                    outs.len() == 1 + spec.layers,
                    "decode step returned {} outputs, want probs + {} kv rows",
                    outs.len(),
                    spec.layers
                );
                let kv_rows = outs.split_off(1);
                kv.append(&kv_rows)?;
                step_probs.push(outs.pop().expect("probs output"));
                Ok(())
            })();
            if result.is_err() {
                break;
            }
        }
        // The request exits here on every path: the lease gives its slab
        // bytes back on drop (error paths included).
        drop(slab);
        metrics.kv_resident_bytes = self.pool.device.kv_high_water_bytes();
        result?;
        Ok(DecodeOutput { generated, step_probs, steps: total, metrics })
    }

    /// Tiers 1–3 (replay / record / interpret), with error-driven replay
    /// demotion. Extracted from `run` so the ladder can retry it whole.
    fn run_tiered(&mut self, prog: &Program, inputs: &[Tensor]) -> Result<ExecOutput> {
        let t_start = Instant::now();
        let m = &prog.module;
        let mut metrics = RunMetrics::default();
        let mut env = SymEnv::new();
        env.bind_params(m, inputs)?;

        let before = self.stats_snapshot();

        // Record this dispatch's binding vector in the shared traffic
        // histogram and read the bucket-policy epoch once: the plan key
        // embeds it, so plans recorded under an older bucket family become
        // unreachable after a swap and retire through the FIFO below.
        let bindings = binding_vector(&env);
        self.switch.histogram.record_bindings(&bindings);
        let epoch = self.switch.epoch();
        metrics.policy_epoch = epoch;

        let mut outputs: Option<Vec<Tensor>> = None;
        let mut record_key: Option<PlanKey> = None;
        let mut demoted = false;
        if self.opts.plan_cache {
            let key = PlanKey { program: prog.id, bindings, epoch };
            match self.plans.get(&key).cloned() {
                Some(plan) => {
                    if plan.param_guards_hold(inputs) {
                        match self.replay(prog, inputs, &plan, &mut env, &mut metrics) {
                            Ok(Some(outs)) => {
                                self.plan_stats.hits += 1;
                                metrics.plan_hits += 1;
                                metrics.launch_elems += plan.launch_elems;
                                metrics.padded_elems += plan.padded_elems;
                                outputs = Some(outs);
                            }
                            Ok(None) => {}
                            Err(_e) => {
                                // Device/transfer fault mid-replay: demote
                                // this request to the interpret tier. The
                                // plan stays installed (the fault is
                                // transient, the plan is not stale). The
                                // replay's device leases unwound with it,
                                // so the arena accounting is already clean.
                                metrics.demotions += 1;
                                demoted = true;
                                env = SymEnv::new();
                                env.bind_params(m, inputs)?;
                            }
                        }
                    }
                    if outputs.is_none() && !demoted {
                        // Stale host-shape assumption: this request is
                        // interpreted; the cached plan stays (the common
                        // shape keeps replaying).
                        self.plan_stats.guard_misses += 1;
                        metrics.plan_guard_misses += 1;
                    }
                }
                None => record_key = Some(key),
            }
        }

        let outputs = match outputs {
            Some(o) => o,
            None => {
                let mut rec = record_key.as_ref().map(|_| PlanRecorder::new());
                if rec.is_some() {
                    self.plan_stats.misses += 1;
                    metrics.plan_misses += 1;
                    env.elem_log = Some(Vec::new());
                }
                let outs = self.interpret(prog, inputs, &mut env, &mut metrics, rec.as_mut())?;
                if let (Some(key), Some(rec)) = (record_key, rec) {
                    let log = env.elem_log.take().unwrap_or_default();
                    let observed = rec.observed().clone();
                    if let Some(mut plan) = rec.finish(m, prog, &log) {
                        // Replays skip the interpret tier, so the plan
                        // carries the recording run's fused-launch element
                        // totals to keep the padding counters flowing.
                        plan.launch_elems = metrics.launch_elems;
                        plan.padded_elems = metrics.padded_elems;
                        // Symbolic memory plan: instantiate the program's
                        // compile-time slot assignment for this binding
                        // (observed-peak fallback when it declines).
                        if self.opts.device_resident
                            && self.opts.runtime.memory_plan
                            && !observed.is_empty()
                        {
                            let mp = self.mem_plan_for(prog);
                            let bindings: HashMap<crate::shape::SymId, i64> =
                                key.bindings.iter().copied().collect();
                            plan.memory =
                                mp.instantiate(&bindings, self.opts.policy, &observed);
                        }
                        // The install's capacity promise is a Reserve-class
                        // lease: dropped (and therefore shrunk) when FIFO
                        // eviction drops the plan. Un-armed by design — the
                        // record path stays fault-silent.
                        let reserve_bytes = plan
                            .memory
                            .as_ref()
                            .map(|pm| pm.planned_peak_bytes)
                            .unwrap_or(plan.device_peak_bytes);
                        plan.reserve = self
                            .pool
                            .device
                            .acquire(
                                crate::runtime::buffers::ResidencyClass::Reserve,
                                reserve_bytes,
                                None,
                            )
                            .ok();
                        while self.plans.len() >= self.max_plans.max(1) {
                            match self.plan_order.pop_front() {
                                Some(old) => {
                                    // FIFO drop: release exactly the weight
                                    // pins this plan took so the library may
                                    // evict entries no live plan references.
                                    self.plans.remove(&old);
                                    for wk in self.plan_pins.remove(&old).unwrap_or_default() {
                                        self.library.unpin_weight(&wk);
                                    }
                                }
                                None => break,
                            }
                        }
                        let pinned = self.pin_plan_weights(key.program, &plan);
                        self.plan_pins.insert(key.clone(), pinned);
                        self.plans.insert(key.clone(), Arc::new(plan));
                        self.plan_order.push_back(key);
                        self.plan_stats.entries = self.plans.len();
                    }
                }
                outs
            }
        };

        // Fold in component-level stats for this run.
        self.fold_stats(&mut metrics, &before);
        metrics.total_time = t_start.elapsed();
        Ok(ExecOutput { outputs, metrics })
    }

    /// Pin every cached-weight reference in a freshly installed plan;
    /// returns the keys whose pin actually took (eviction releases exactly
    /// these — see `plan_pins`). One rule per step, shared with the batch
    /// plan installer (`Self::pin_step_weight` in `runtime::batching`).
    fn pin_plan_weights(&mut self, program: u64, plan: &LaunchPlan) -> Vec<WeightKey> {
        let mut pinned = Vec::new();
        for step in &plan.steps {
            Self::pin_step_weight(&mut self.library, program, step, &mut pinned);
        }
        pinned
    }

    /// Tier 1/2: interpret the whole step sequence (optionally recording a
    /// launch plan).
    fn interpret(
        &mut self,
        prog: &Program,
        inputs: &[Tensor],
        env: &mut SymEnv,
        metrics: &mut RunMetrics,
        rec: Option<&mut PlanRecorder>,
    ) -> Result<Vec<Tensor>> {
        let m = &prog.module;
        let mut vals: Vec<Option<Rc<Tensor>>> = vec![None; m.instrs.len()];
        // Materialize params and constants.
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => vals[id] = Some(Rc::new(inputs[*index].clone())),
                Op::Const { lit, dims } => {
                    vals[id] = Some(Rc::new(Tensor::from_literal(lit, dims)))
                }
                _ => {}
            }
        }
        self.interpret_range(prog, 0, env, &mut vals, metrics, rec)?;
        m.outputs
            .iter()
            .map(|&o| {
                vals[o]
                    .as_deref()
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("output %{o} was deallocated"))
            })
            .collect()
    }

    /// Interpret steps `from..` against an already-seeded value store. Also
    /// the replay fallback for data-dependent suffixes.
    fn interpret_range(
        &mut self,
        prog: &Program,
        from: usize,
        env: &mut SymEnv,
        vals: &mut [Option<Rc<Tensor>>],
        metrics: &mut RunMetrics,
        mut rec: Option<&mut PlanRecorder>,
    ) -> Result<()> {
        let m = &prog.module;
        for (si, step) in prog.steps.iter().enumerate().skip(from) {
            match step {
                Step::EvalHost { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &vals[..])?;
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| vals[o].as_deref().unwrap()).collect();
                    let t = eval_op(&ins.op, &operands, &out_dims, ins.ty.dtype)
                        .with_context(|| format!("host op %{value}"))?;
                    metrics.host_ops += 1;
                    if let Some(r) = rec.as_deref_mut() {
                        r.push(PlannedStep::EvalHost { value: *value, out_dims });
                    }
                    vals[*value] = Some(Rc::new(t));
                }
                Step::Bitcast { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &vals[..])?;
                    let src = vals[ins.operands[0]].as_deref().unwrap().clone();
                    metrics.bitcasts += 1;
                    if let Some(r) = rec.as_deref_mut() {
                        r.push(PlannedStep::Bitcast { value: *value, out_dims: out_dims.clone() });
                    }
                    vals[*value] = Some(Rc::new(src.with_dims(&out_dims)?));
                }
                Step::LaunchOp { value } => {
                    let ins = &m.instrs[*value];
                    // Data-dependent outputs (Unique) resolve their own
                    // extent; everything else resolves from the shape env.
                    let out_dims = if matches!(ins.op, Op::Unique) {
                        // No plan can predict this extent: the flow from
                        // here on stays interpreted. Freeze the shape-read
                        // log too — suffix reads must not become guards.
                        if let Some(r) = rec.as_deref_mut() {
                            r.mark_suffix(si);
                            if let Some(log) = env.elem_log.take() {
                                r.stash_elem_log(log);
                            }
                        }
                        vec![]
                    } else {
                        env.resolve_dims(m, &ins.ty.dims, &vals[..])?
                    };
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| vals[o].as_deref().unwrap()).collect();
                    for o in &operands {
                        metrics.mem_bytes += o.byte_size() as u64;
                    }
                    let tk = Instant::now();
                    let t = eval_op(&ins.op, &operands, &out_dims, ins.ty.dtype)
                        .with_context(|| format!("singleton kernel %{value}"))?;
                    metrics.kernel_time += tk.elapsed();
                    metrics.mem_kernels += 1;
                    metrics.mem_bytes += t.byte_size() as u64;
                    if matches!(ins.op, Op::Unique) {
                        env.set_datadep(m, *value, t.dims[0] as i64);
                    } else if let Some(r) = rec.as_deref_mut() {
                        r.push(PlannedStep::LaunchOp { value: *value, out_dims });
                    }
                    vals[*value] = Some(Rc::new(t));
                }
                Step::LibraryCall { value } => {
                    let ins = &m.instrs[*value];
                    let a = vals[ins.operands[0]].as_deref().unwrap();
                    let b = vals[ins.operands[1]].as_deref().unwrap();
                    metrics.lib_bytes += (a.byte_size() + b.byte_size()) as u64;
                    let build0 = self.library.stats.build_time;
                    let exec0 = self.library.stats.exec_time;
                    let key = self.library.key_for(a, b)?;
                    // Static RHS operands are served from the persistent
                    // device-side weight cache: upload once per program,
                    // then by reference (transfer deltas fold in at run
                    // level from LibraryStats).
                    let weight = if self.opts.device_resident && self.opts.runtime.weight_cache {
                        weight_ref_of(m, ins.operands[1]).filter(|_| b.dtype == DType::F32)
                    } else {
                        None
                    };
                    let t = if let Some(w) = &weight {
                        let wdev = self.library.weight_device(
                            WeightKey { program: prog.id, value: w.value },
                            b,
                            &key.rhs_dims(),
                            w.validate,
                        )?;
                        let (dt, actual) = self.library.matmul_device(
                            GemmSrc::Host(a),
                            GemmSrc::Weight { dt: wdev, actual: &b.dims },
                            key,
                        )?;
                        self.library.readback(&dt, &actual)?
                    } else {
                        self.library.matmul_with_key(a, b, key)?
                    };
                    metrics.lib_time += self.library.stats.exec_time - exec0;
                    // On-demand library builds are one-time compile cost
                    // (vendor libraries ship pre-built).
                    metrics.compile_time += self.library.stats.build_time - build0;
                    metrics.lib_calls += 1;
                    metrics.lib_bytes += t.byte_size() as u64;
                    if let Some(r) = rec.as_deref_mut() {
                        if self.opts.device_resident {
                            // Residency modeling only applies when replays
                            // actually hold device buffers.
                            let out_bytes =
                                (key.batch.max(1) * key.m * key.n * 4) as u64;
                            r.note_device_out(*value, out_bytes);
                        }
                        r.push(PlannedStep::LibraryCall { value: *value, key, weight });
                    }
                    vals[*value] = Some(Rc::new(t));
                }
                Step::LaunchFused { idx } => {
                    let fl = &prog.fused[*idx];
                    // 1. Resolve actual extents of the group's symbols.
                    let mut actual: HashMap<crate::shape::SymId, usize> =
                        HashMap::with_capacity(fl.syms.len());
                    for &s in &fl.syms {
                        let v = env.resolve_dim(m, crate::shape::Dim::Sym(s), &vals[..])?;
                        actual.insert(s, v);
                    }
                    // 2. Cache lookup / compile.
                    let (kernel, _buckets) =
                        self.cache.get_or_compile(m, &fl.group, &fl.sig, &actual)?;
                    // Log this launch site (fused index + actual extents)
                    // in the shared histogram: the re-bucketing pass warms
                    // exactly these sites under candidate boundaries before
                    // flipping the epoch. Only the interpret tier passes
                    // here, so the map tracks the distinct shape set.
                    let actual_vec: Vec<usize> =
                        fl.syms.iter().map(|s| actual[s]).collect();
                    self.switch.histogram.record_site(prog.id, *idx, &fl.syms, &actual_vec);
                    // Speculative neighbor-bucket warming: while this
                    // request is being recorded (= a shape the process has
                    // not served before), enqueue background compiles for
                    // the next bucket of its dynamic symbols so growing
                    // sequence lengths find their kernels resident. Replays
                    // never reach this code; warm failures are ignored
                    // (the demand path re-compiles and reports properly).
                    if self.opts.runtime.speculative_warm && rec.is_some() {
                        let _ = self.cache.prefetch_neighbor(m, &fl.group, &fl.sig, &actual);
                    }
                    // 3. Marshal inputs: pad to bucket extents when
                    //    needed; aligned inputs are passed by reference
                    //    (no host copy before literal marshalling).
                    let spec = &kernel.spec;
                    let mut owned: Vec<Tensor> =
                        Vec::with_capacity(spec.extent_locals.len() + 2);
                    let mut arg_ix: Vec<isize> = Vec::with_capacity(
                        fl.inputs.len() + spec.extent_locals.len(),
                    );
                    for (i, &v) in fl.inputs.iter().enumerate() {
                        let src = vals[v].as_deref().unwrap();
                        let bucket_elems =
                            spec.input_dims[i].iter().product::<usize>() as u64;
                        metrics.launch_elems += bucket_elems;
                        if src.dims == spec.input_dims[i] {
                            arg_ix.push(-(v as isize) - 1);
                            metrics.mem_bytes += src.byte_size() as u64;
                        } else {
                            metrics.pad_copies += 1;
                            metrics.padded_elems +=
                                bucket_elems - src.dims.iter().product::<usize>() as u64;
                            let padded = pad_box(
                                src,
                                &spec.input_dims[i],
                                if self.opts.pooled_buffers { Some(&mut self.pool) } else { None },
                            )?;
                            // The kernel reads the full bucket-shaped
                            // buffer: padding is real off-chip traffic
                            // (the modeled cost of shape-adaptive
                            // bucketing, and the source of the Fig. 4
                            // static/dynamic gap).
                            metrics.mem_bytes += padded.byte_size() as u64;
                            arg_ix.push(owned.len() as isize);
                            owned.push(padded);
                        }
                    }
                    let mut extent_vals: Vec<i32> =
                        Vec::with_capacity(spec.extent_locals.len());
                    for &li in &spec.extent_locals {
                        let v = actual[&fl.syms[li]];
                        extent_vals.push(v as i32);
                        arg_ix.push(owned.len() as isize);
                        owned.push(Tensor::i32(&[], vec![v as i32]));
                    }
                    let args: Vec<&Tensor> = arg_ix
                        .iter()
                        .map(|&ix| {
                            if ix >= 0 {
                                &owned[ix as usize]
                            } else {
                                vals[(-ix - 1) as usize].as_deref().unwrap()
                            }
                        })
                        .collect();
                    for a in &args {
                        metrics.h2d_bytes += a.byte_size() as u64;
                    }
                    // 4. Launch.
                    let tk = Instant::now();
                    let out =
                        kernel.exe.run(&args, &spec.out_dims, spec.out_dtype).with_context(
                            || format!("launching fused kernel {}", spec.name),
                        )?;
                    metrics.kernel_time += tk.elapsed();
                    metrics.mem_kernels += 1;
                    drop(args);
                    // Return pooled pad buffers.
                    if self.opts.pooled_buffers {
                        for a in owned {
                            if let Data::F32(v) = a.data {
                                if v.capacity() > 0 {
                                    self.pool.free_f32(v);
                                }
                            }
                        }
                    }
                    // The kernel writes the bucket-shaped output.
                    metrics.mem_bytes += out.byte_size() as u64;
                    metrics.d2h_bytes += out.byte_size() as u64;
                    // 5. Crop to actual extents.
                    let actual_out =
                        env.resolve_dims(m, &m.ty(fl.root).dims, &vals[..])?;
                    metrics.launch_elems += spec.out_dims.iter().product::<usize>() as u64;
                    let out = if out.dims == actual_out {
                        out
                    } else {
                        metrics.pad_copies += 1;
                        metrics.padded_elems += (spec.out_dims.iter().product::<usize>()
                            - actual_out.iter().product::<usize>())
                            as u64;
                        crop_box(&out, &actual_out)?
                    };
                    if let Some(r) = rec.as_deref_mut() {
                        if r.active() {
                            let extents_host: Vec<Tensor> = extent_vals
                                .iter()
                                .map(|&v| Tensor::i32(&[], vec![v]))
                                .collect();
                            let extents_dev = if self.opts.device_resident {
                                extents_host
                                    .iter()
                                    .map(|t| self.device.h2d(t).map(Arc::new))
                                    .collect::<Result<Vec<_>>>()?
                            } else {
                                Vec::new()
                            };
                            if self.opts.device_resident {
                                let out_bytes = (spec.out_dims.iter().product::<usize>()
                                    * spec.out_dtype.byte_size())
                                    as u64;
                                r.note_device_out(fl.root, out_bytes);
                            }
                            r.push(PlannedStep::LaunchFused {
                                idx: *idx,
                                kernel: kernel.clone(),
                                extents_host,
                                extents_dev,
                                out_actual: out.dims.clone(),
                            });
                        }
                    }
                    vals[fl.root] = Some(Rc::new(out));
                }
                Step::Dealloc { value } => {
                    // Liveness-placed release; Rc drop returns memory.
                    if let Some(r) = rec.as_deref_mut() {
                        r.note_dealloc(*value);
                        r.push(PlannedStep::Dealloc { value: *value });
                    }
                    vals[*value] = None;
                }
            }
        }
        Ok(())
    }

    /// Materialize a host view of a value: either the host slot, or a
    /// readback (+ crop to actual extents) of the device-resident buffer,
    /// memoized into the host slot.
    fn host_value(
        device: &Device,
        metrics: &mut RunMetrics,
        host: &mut [Option<Rc<Tensor>>],
        dev: &[Option<DevSlot>],
        v: usize,
    ) -> Result<Rc<Tensor>> {
        if let Some(t) = &host[v] {
            return Ok(t.clone());
        }
        let d = dev[v]
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("value %{v} has no live buffer"))?;
        let full = device.d2h(&d.dt)?;
        metrics.d2h_bytes += full.byte_size() as u64;
        let t = if full.dims == d.actual {
            full
        } else {
            metrics.pad_copies += 1;
            crop_box(&full, &d.actual)?
        };
        let rc = Rc::new(t);
        host[v] = Some(rc.clone());
        Ok(rc)
    }

    /// Tier 3: replay a recorded plan. Returns `Ok(None)` when a host-shape
    /// guard fails (caller falls back to interpretation).
    fn replay(
        &mut self,
        prog: &Program,
        inputs: &[Tensor],
        plan: &LaunchPlan,
        env: &mut SymEnv,
        out_metrics: &mut RunMetrics,
    ) -> Result<Option<Vec<Tensor>>> {
        // Work against scratch metrics: a guard miss mid-replay discards
        // the partial prefix's counters (the request is then fully
        // re-interpreted), so nothing is double-counted.
        let mut scratch = RunMetrics::default();
        let metrics = &mut scratch;
        let m = &prog.module;
        let device = self.device.clone();
        let n = m.instrs.len();
        let mut host: Vec<Option<Rc<Tensor>>> = vec![None; n];
        let mut dev: Vec<Option<DevSlot>> = vec![None; n];
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => host[id] = Some(Rc::new(inputs[*index].clone())),
                Op::Const { lit, dims } => {
                    host[id] = Some(Rc::new(Tensor::from_literal(lit, dims)))
                }
                _ => {}
            }
        }
        // Planner-on: acquire the whole planned extent up front (the one
        // armed OOM seam of this replay); every DevSlot then indexes a
        // planned slot and carries no lease of its own. Planner-off: each
        // device output acquires its own Plan-class lease below. Either
        // way, early returns and faults release by drop — no manual
        // unwinding.
        let planned = plan.memory.is_some();
        let _extent: Option<crate::runtime::buffers::ArenaLease> = match &plan.memory {
            Some(pm) => Some(self.pool.device.acquire(
                crate::runtime::buffers::ResidencyClass::Plan,
                pm.planned_peak_bytes,
                self.device.faults().map(|f| f.as_ref()),
            )?),
            None => None,
        };

        for step in &plan.steps {
            match step {
                PlannedStep::EvalHost { value, out_dims } => {
                    let ins = &m.instrs[*value];
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| host[o].as_deref().unwrap()).collect();
                    let t = eval_op(&ins.op, &operands, out_dims, ins.ty.dtype)
                        .with_context(|| format!("host op %{value} (replay)"))?;
                    metrics.host_ops += 1;
                    drop(operands);
                    let t = Rc::new(t);
                    if let Some(gs) = plan.host_guards.get(value) {
                        if !host_guards_hold(gs, &t) {
                            // Stale host-shape assumption: the prefix's
                            // leases (and the planned extent) release by
                            // drop; scratch metrics are discarded with
                            // this return.
                            return Ok(None);
                        }
                    }
                    host[*value] = Some(t);
                }
                PlannedStep::Bitcast { value, out_dims } => {
                    let src = Self::host_value(
                        &device,
                        metrics,
                        &mut host,
                        &dev,
                        m.instrs[*value].operands[0],
                    )?;
                    metrics.bitcasts += 1;
                    host[*value] = Some(Rc::new((*src).clone().with_dims(out_dims)?));
                }
                PlannedStep::LaunchOp { value, out_dims } => {
                    let ins = &m.instrs[*value];
                    let mut ops: Vec<Rc<Tensor>> = Vec::with_capacity(ins.operands.len());
                    for &o in &ins.operands {
                        ops.push(Self::host_value(&device, metrics, &mut host, &dev, o)?);
                    }
                    let operands: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                    for o in &operands {
                        metrics.mem_bytes += o.byte_size() as u64;
                    }
                    let tk = Instant::now();
                    let t = eval_op(&ins.op, &operands, out_dims, ins.ty.dtype)
                        .with_context(|| format!("singleton kernel %{value} (replay)"))?;
                    metrics.kernel_time += tk.elapsed();
                    metrics.mem_kernels += 1;
                    metrics.mem_bytes += t.byte_size() as u64;
                    host[*value] = Some(Rc::new(t));
                }
                PlannedStep::LibraryCall { value, key, weight } => {
                    let ins = &m.instrs[*value];
                    let (a_id, b_id) = (ins.operands[0], ins.operands[1]);
                    let build0 = self.library.stats.build_time;
                    let exec0 = self.library.stats.exec_time;
                    if self.opts.device_resident {
                        // Chain dev→dev wherever a device-resident operand
                        // exists; the library adapts buckets and masks
                        // garbage pad lanes on device. Host materialization
                        // happens only for operands with no live buffer.
                        let a_host = if dev[a_id].is_none() {
                            Some(Self::host_value(&device, metrics, &mut host, &dev, a_id)?)
                        } else {
                            None
                        };
                        let w_dev = if let Some(w) = weight {
                            // Const/Param operands are host-materialized at
                            // replay start; serve the device copy from the
                            // persistent weight cache (upload-once).
                            let bt = host[b_id]
                                .clone()
                                .expect("weight operand must be host-materialized");
                            let dt = self.library.weight_device(
                                WeightKey { program: prog.id, value: w.value },
                                &bt,
                                &key.rhs_dims(),
                                w.validate,
                            )?;
                            let dims = bt.dims.clone();
                            Some((dt, dims))
                        } else {
                            None
                        };
                        let b_host = if w_dev.is_none() && dev[b_id].is_none() {
                            Some(Self::host_value(&device, metrics, &mut host, &dev, b_id)?)
                        } else {
                            None
                        };
                        let src_a = match (&a_host, dev[a_id].as_ref()) {
                            (Some(t), _) => GemmSrc::Host(t),
                            (None, Some(s)) => GemmSrc::Dev {
                                dt: &s.dt,
                                actual: &s.actual,
                                zero_padded: s.zero_padded,
                            },
                            _ => unreachable!("lhs has neither host nor device value"),
                        };
                        let src_b = match (&w_dev, &b_host, dev[b_id].as_ref()) {
                            (Some((dt, dims)), _, _) => {
                                GemmSrc::Weight { dt: dt.clone(), actual: dims }
                            }
                            (None, Some(t), _) => GemmSrc::Host(t),
                            (None, None, Some(s)) => GemmSrc::Dev {
                                dt: &s.dt,
                                actual: &s.actual,
                                zero_padded: s.zero_padded,
                            },
                            _ => unreachable!("rhs has neither host nor device value"),
                        };
                        let a_bytes = src_a.actual_byte_size();
                        let b_bytes = src_b.actual_byte_size();
                        let (dt, actual) = self.library.matmul_device(src_a, src_b, *key)?;
                        metrics.lib_bytes += a_bytes + b_bytes;
                        metrics.lib_bytes += (actual.iter().product::<usize>() * 4) as u64;
                        let lease = if planned {
                            None
                        } else {
                            Some(self.pool.device.acquire(
                                crate::runtime::buffers::ResidencyClass::Plan,
                                dt.byte_size() as u64,
                                self.device.faults().map(|f| f.as_ref()),
                            )?)
                        };
                        dev[*value] = Some(DevSlot { dt, actual, zero_padded: true, lease });
                    } else {
                        let a = Self::host_value(&device, metrics, &mut host, &dev, a_id)?;
                        let b = Self::host_value(&device, metrics, &mut host, &dev, b_id)?;
                        metrics.lib_bytes += (a.byte_size() + b.byte_size()) as u64;
                        let t = self.library.matmul_with_key(&a, &b, *key)?;
                        metrics.lib_bytes += t.byte_size() as u64;
                        host[*value] = Some(Rc::new(t));
                    }
                    metrics.lib_time += self.library.stats.exec_time - exec0;
                    metrics.compile_time += self.library.stats.build_time - build0;
                    metrics.lib_calls += 1;
                }
                PlannedStep::LaunchFused {
                    idx,
                    kernel,
                    extents_host,
                    extents_dev,
                    out_actual,
                } => {
                    let fl = &prog.fused[*idx];
                    let spec = &kernel.spec;
                    // The recorded kernel replaces signature hashing and
                    // the bucket-cache lookup; account it as a hit so the
                    // cache's reuse stats stay meaningful.
                    self.cache.stats.hits += 1;
                    if self.opts.device_resident {
                        enum Src {
                            Owned(usize),
                            Slot(usize),
                            Ext(usize),
                        }
                        let mut owned: Vec<DeviceTensor> = Vec::new();
                        let mut srcs: Vec<Src> =
                            Vec::with_capacity(fl.inputs.len() + extents_dev.len());
                        for (i, &v) in fl.inputs.iter().enumerate() {
                            let expected = &spec.input_dims[i];
                            if let Some(d) = dev[v].as_ref() {
                                if &d.dt.dims == expected {
                                    // Device-resident chaining: the
                                    // producer's bucket-shaped buffer is
                                    // consumed in place. Valid output
                                    // lanes of every fusable op depend
                                    // only on valid input lanes (dynamic
                                    // reduce axes are masked in-kernel),
                                    // so pad-lane garbage never reaches
                                    // the cropped result.
                                    metrics.mem_bytes += d.dt.byte_size() as u64;
                                    srcs.push(Src::Slot(v));
                                    continue;
                                }
                            }
                            let t =
                                Self::host_value(&device, metrics, &mut host, &dev, v)?;
                            let up = if t.dims == *expected {
                                device.h2d(&t)?
                            } else {
                                metrics.pad_copies += 1;
                                let padded = pad_box(
                                    &t,
                                    expected,
                                    if self.opts.pooled_buffers {
                                        Some(&mut self.pool)
                                    } else {
                                        None
                                    },
                                )?;
                                let dt = device.h2d(&padded)?;
                                if self.opts.pooled_buffers {
                                    if let Data::F32(v) = padded.data {
                                        if v.capacity() > 0 {
                                            self.pool.free_f32(v);
                                        }
                                    }
                                }
                                dt
                            };
                            metrics.mem_bytes += up.byte_size() as u64;
                            metrics.h2d_bytes += up.byte_size() as u64;
                            srcs.push(Src::Owned(owned.len()));
                            owned.push(up);
                        }
                        for i in 0..extents_dev.len() {
                            srcs.push(Src::Ext(i));
                        }
                        let args: Vec<&DeviceTensor> = srcs
                            .iter()
                            .map(|s| match s {
                                Src::Owned(i) => &owned[*i],
                                Src::Slot(v) => &dev[*v].as_ref().unwrap().dt,
                                Src::Ext(i) => extents_dev[*i].as_ref(),
                            })
                            .collect();
                        let tk = Instant::now();
                        let out = kernel
                            .exe
                            .run_on_device(&args, &spec.out_dims, spec.out_dtype)
                            .with_context(|| {
                                format!("replaying fused kernel {}", spec.name)
                            })?;
                        metrics.kernel_time += tk.elapsed();
                        metrics.mem_kernels += 1;
                        metrics.mem_bytes += out.byte_size() as u64;
                        drop(args);
                        let lease = if planned {
                            None
                        } else {
                            Some(self.pool.device.acquire(
                                crate::runtime::buffers::ResidencyClass::Plan,
                                out.byte_size() as u64,
                                self.device.faults().map(|f| f.as_ref()),
                            )?)
                        };
                        dev[fl.root] = Some(DevSlot {
                            dt: out,
                            actual: out_actual.clone(),
                            zero_padded: false,
                            lease,
                        });
                    } else {
                        // Host-path replay: recorded marshalling decisions,
                        // no resolution or cache hashing.
                        let mut owned: Vec<Tensor> = Vec::new();
                        let mut arg_ix: Vec<isize> =
                            Vec::with_capacity(fl.inputs.len() + extents_host.len());
                        for (i, &v) in fl.inputs.iter().enumerate() {
                            let src = host[v].as_deref().unwrap();
                            if src.dims == spec.input_dims[i] {
                                arg_ix.push(-(v as isize) - 1);
                                metrics.mem_bytes += src.byte_size() as u64;
                            } else {
                                metrics.pad_copies += 1;
                                let padded = pad_box(
                                    src,
                                    &spec.input_dims[i],
                                    if self.opts.pooled_buffers {
                                        Some(&mut self.pool)
                                    } else {
                                        None
                                    },
                                )?;
                                metrics.mem_bytes += padded.byte_size() as u64;
                                arg_ix.push(owned.len() as isize);
                                owned.push(padded);
                            }
                        }
                        let args: Vec<&Tensor> = arg_ix
                            .iter()
                            .map(|&ix| {
                                if ix >= 0 {
                                    &owned[ix as usize]
                                } else {
                                    host[(-ix - 1) as usize].as_deref().unwrap()
                                }
                            })
                            .chain(extents_host.iter())
                            .collect();
                        for a in &args {
                            metrics.h2d_bytes += a.byte_size() as u64;
                        }
                        let tk = Instant::now();
                        let out = kernel
                            .exe
                            .run(&args, &spec.out_dims, spec.out_dtype)
                            .with_context(|| {
                                format!("replaying fused kernel {}", spec.name)
                            })?;
                        metrics.kernel_time += tk.elapsed();
                        metrics.mem_kernels += 1;
                        drop(args);
                        if self.opts.pooled_buffers {
                            for a in owned {
                                if let Data::F32(v) = a.data {
                                    if v.capacity() > 0 {
                                        self.pool.free_f32(v);
                                    }
                                }
                            }
                        }
                        metrics.mem_bytes += out.byte_size() as u64;
                        metrics.d2h_bytes += out.byte_size() as u64;
                        let out = if &out.dims == out_actual {
                            out
                        } else {
                            metrics.pad_copies += 1;
                            crop_box(&out, out_actual)?
                        };
                        host[fl.root] = Some(Rc::new(out));
                    }
                }
                PlannedStep::Dealloc { value } => {
                    // Dropping the slot releases its lease (planner-off);
                    // planned slots just free their entry in the extent.
                    dev[*value] = None;
                    host[*value] = None;
                }
            }
        }

        // Data-dependent suffix: hand the live values to the interpreter.
        if plan.suffix_start < prog.steps.len() {
            for v in 0..n {
                if dev[v].is_some() && host[v].is_none() {
                    Self::host_value(&device, metrics, &mut host, &dev, v)?;
                }
            }
            for d in dev.iter_mut() {
                *d = None;
            }
            self.interpret_range(prog, plan.suffix_start, env, &mut host, metrics, None)?;
        }

        let mut outputs = Vec::with_capacity(m.outputs.len());
        for &o in &m.outputs {
            let t = Self::host_value(&device, metrics, &mut host, &dev, o)
                .with_context(|| format!("output %{o} was deallocated"))?;
            outputs.push((*t).clone());
        }
        drop(dev); // release (park) every remaining per-buffer lease
        // The honest per-class peak: live + parked bytes of the cached
        // allocator model (planner-off), or the planned extents
        // (planner-on, which re-park and reuse exactly at each replay).
        metrics.device_resident_bytes = self
            .pool
            .device
            .footprint_high_water(crate::runtime::buffers::ResidencyClass::Plan);
        if let Some(pm) = &plan.memory {
            metrics.planned_peak_bytes = pm.planned_peak_bytes;
            metrics.mem_plan_reuse_bytes += pm.reuse_bytes;
        }
        *out_metrics += &scratch;
        Ok(Some(outputs))
    }
}

/// First-max argmax over a probability row — the decode loop's
/// deterministic sampler (ties break to the lowest token id, so every
/// tier and every batch composition picks the same token).
pub fn argmax_token(probs: &Tensor) -> i64 {
    let Ok(v) = probs.as_f32() else { return 0 };
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i64
}

/// Copy `src` into a fresh tensor of `bucket_dims` (each `>= src.dims[i]`),
/// filling the tail with zeros. The valid data occupies the prefix box.
pub fn pad_box(
    src: &Tensor,
    bucket_dims: &[usize],
    pool: Option<&mut BufferPool>,
) -> Result<Tensor> {
    anyhow::ensure!(src.rank() == bucket_dims.len(), "pad_box rank mismatch");
    let n: usize = bucket_dims.iter().product();
    match &src.data {
        Data::F32(v) => {
            let mut out = match pool {
                Some(p) => p.alloc_f32(n, 0.0),
                None => vec![0.0; n],
            };
            copy_box(v, &src.dims, &mut out, bucket_dims);
            Ok(Tensor::f32(bucket_dims, out))
        }
        Data::I64(v) => {
            let mut out = vec![0i64; n];
            copy_box(v, &src.dims, &mut out, bucket_dims);
            Ok(Tensor::i64(bucket_dims, out))
        }
        Data::I32(v) => {
            let mut out = vec![0i32; n];
            copy_box(v, &src.dims, &mut out, bucket_dims);
            Ok(Tensor::i32(bucket_dims, out))
        }
        Data::Pred(_) => anyhow::bail!("pred pad unsupported"),
    }
}

/// Extract the prefix box `actual_dims` from a bucket-shaped tensor.
pub fn crop_box(src: &Tensor, actual_dims: &[usize]) -> Result<Tensor> {
    anyhow::ensure!(src.rank() == actual_dims.len(), "crop_box rank mismatch");
    let n: usize = actual_dims.iter().product();
    match &src.data {
        Data::F32(v) => {
            let mut out = vec![0.0f32; n];
            copy_box_rev(v, &src.dims, &mut out, actual_dims);
            Ok(Tensor::f32(actual_dims, out))
        }
        Data::I64(v) => {
            let mut out = vec![0i64; n];
            copy_box_rev(v, &src.dims, &mut out, actual_dims);
            Ok(Tensor::i64(actual_dims, out))
        }
        Data::I32(v) => {
            let mut out = vec![0i32; n];
            copy_box_rev(v, &src.dims, &mut out, actual_dims);
            Ok(Tensor::i32(actual_dims, out))
        }
        Data::Pred(_) => anyhow::bail!("pred crop unsupported"),
    }
}

/// Copy the `src_dims` box of `src` into the top-left corner of a
/// `dst_dims` buffer. Row-run optimized: contiguous over the last axis.
fn copy_box<T: Copy>(src: &[T], src_dims: &[usize], dst: &mut [T], dst_dims: &[usize]) {
    if src_dims.is_empty() {
        dst[0] = src[0];
        return;
    }
    let row = *src_dims.last().unwrap();
    let rows: usize = src_dims[..src_dims.len() - 1].iter().product();
    let src_strides = strides_of(src_dims);
    let dst_strides = strides_of(dst_dims);
    for r in 0..rows {
        // Unravel row index over the leading dims.
        let mut rem = r;
        let mut src_off = 0usize;
        let mut dst_off = 0usize;
        for i in (0..src_dims.len() - 1).rev() {
            let c = rem % src_dims[i];
            rem /= src_dims[i];
            src_off += c * src_strides[i];
            dst_off += c * dst_strides[i];
        }
        dst[dst_off..dst_off + row].copy_from_slice(&src[src_off..src_off + row]);
    }
}

/// Copy the top-left `dst_dims` box of `src` (shaped `src_dims`) out.
fn copy_box_rev<T: Copy>(src: &[T], src_dims: &[usize], dst: &mut [T], dst_dims: &[usize]) {
    if dst_dims.is_empty() {
        dst[0] = src[0];
        return;
    }
    let row = *dst_dims.last().unwrap();
    let rows: usize = dst_dims[..dst_dims.len() - 1].iter().product();
    let src_strides = strides_of(src_dims);
    let dst_strides = strides_of(dst_dims);
    for r in 0..rows {
        let mut rem = r;
        let mut src_off = 0usize;
        let mut dst_off = 0usize;
        for i in (0..dst_dims.len() - 1).rev() {
            let c = rem % dst_dims[i];
            rem /= dst_dims[i];
            src_off += c * src_strides[i];
            dst_off += c * dst_strides[i];
        }
        dst[dst_off..dst_off + row].copy_from_slice(&src[src_off..src_off + row]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, Literal, UnKind};
    use crate::fusion::{plan, FusionOptions};
    use crate::program::generate;
    use crate::runtime::reference::eval_module;
    use crate::shape::Dim;
    use crate::util::prng::Prng;

    fn executor() -> Executor {
        let dev = Arc::new(Device::cpu().unwrap());
        Executor::new(dev, ExecOptions::default())
    }

    fn executor_no_plans() -> Executor {
        let dev = Arc::new(Device::cpu().unwrap());
        Executor::new(
            dev,
            ExecOptions { plan_cache: false, device_resident: false, ..Default::default() },
        )
    }

    fn softmax_prog() -> Program {
        let mut b = Builder::new("softmax");
        let s = b.dyn_dim("rows", 0, 0);
        let c = b.dyn_dim("cols", 0, 1);
        let x = b.param(DType::F32, vec![s, c]);
        let y = b.softmax_last(x).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        generate(m, &p).unwrap()
    }

    #[test]
    fn pad_and_crop_roundtrip() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_box(&t, &[4, 4], None).unwrap();
        assert_eq!(p.dims, vec![4, 4]);
        assert_eq!(p.as_f32().unwrap()[0..3], [1., 2., 3.]);
        assert_eq!(p.as_f32().unwrap()[3], 0.0);
        assert_eq!(p.as_f32().unwrap()[4..7], [4., 5., 6.]);
        let c = crop_box(&p, &[2, 3]).unwrap();
        assert_eq!(c, t);
    }

    #[test]
    fn executes_softmax_against_reference_over_shape_stream() {
        let prog = softmax_prog();
        let mut exec = executor();
        let mut rng = Prng::new(42);
        for (rows, cols) in [(2usize, 3usize), (5, 7), (1, 16), (3, 3), (4, 9)] {
            let data = rng.fill_f32(rows * cols, 2.0);
            let input = Tensor::f32(&[rows, cols], data);
            let got = exec.run(&prog, &[input.clone()]).unwrap();
            let want = eval_module(&prog.module, &[input]).unwrap();
            assert!(
                got.outputs[0].allclose(&want.outputs[0], 1e-5, 1e-5).unwrap(),
                "mismatch at {rows}x{cols}"
            );
        }
        // Re-running the same shape stream triggers zero new compiles:
        // every (pattern, bucket) is already cached — and with the plan
        // cache warm, every request replays its recorded flow.
        let misses_after_first_pass = exec.cache.stats.misses;
        for (rows, cols) in [(2usize, 3usize), (5, 7), (1, 16), (3, 3), (4, 9)] {
            let input = Tensor::f32(&[rows, cols], rng.fill_f32(rows * cols, 2.0));
            let out = exec.run(&prog, &[input]).unwrap();
            assert_eq!(out.metrics.plan_hits, 1, "warm binding must replay");
        }
        assert_eq!(exec.cache.stats.misses, misses_after_first_pass);
        assert!(exec.cache.stats.hits > 0, "bucket reuse must kick in");
        assert_eq!(exec.plan_stats.misses, 5);
        assert_eq!(exec.plan_stats.hits, 5);
        assert_eq!(exec.plan_stats.entries, 5, "one plan per distinct binding vector");
    }

    #[test]
    fn plan_replay_bit_matches_interpreter() {
        // The replayed (device-resident) flow must produce bit-identical
        // outputs to the uncached interpreter path.
        let prog = softmax_prog();
        let mut cached = executor();
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(9);
        for (rows, cols) in [(3usize, 5usize), (3, 5), (3, 5), (6, 2), (3, 5)] {
            let input = Tensor::f32(&[rows, cols], rng.fill_f32(rows * cols, 1.5));
            let a = cached.run(&prog, &[input.clone()]).unwrap();
            let b = plain.run(&prog, &[input]).unwrap();
            assert_eq!(a.outputs, b.outputs, "replay diverged at {rows}x{cols}");
        }
        assert!(cached.plan_stats.hits >= 3);
        assert_eq!(plain.plan_stats.hits, 0);
    }

    #[test]
    fn replay_cuts_host_device_traffic() {
        // Device-resident chaining: the replayed softmax pipeline moves
        // strictly fewer host<->device bytes than the interpreted one.
        let prog = softmax_prog();
        let mut exec = executor();
        let input = Tensor::f32(&[4, 8], vec![0.25; 32]);
        let first = exec.run(&prog, &[input.clone()]).unwrap();
        let second = exec.run(&prog, &[input]).unwrap();
        assert_eq!(second.metrics.plan_hits, 1);
        assert!(
            second.metrics.h2d_bytes < first.metrics.h2d_bytes,
            "replay h2d {} must be below interpret h2d {}",
            second.metrics.h2d_bytes,
            first.metrics.h2d_bytes
        );
        assert!(
            second.metrics.d2h_bytes < first.metrics.d2h_bytes,
            "replay d2h {} must be below interpret d2h {}",
            second.metrics.d2h_bytes,
            first.metrics.d2h_bytes
        );
        assert!(second.metrics.device_resident_bytes > 0);
    }

    #[test]
    fn distinct_bindings_get_distinct_plans() {
        let prog = softmax_prog();
        let mut exec = executor();
        let a = Tensor::f32(&[2, 3], vec![0.1; 6]);
        let b = Tensor::f32(&[5, 7], vec![0.1; 35]);
        exec.run(&prog, &[a.clone()]).unwrap();
        exec.run(&prog, &[b.clone()]).unwrap();
        assert_eq!(exec.plan_stats.entries, 2, "two binding vectors, two plans");
        // Each replays independently.
        let ra = exec.run(&prog, &[a]).unwrap();
        let rb = exec.run(&prog, &[b]).unwrap();
        assert_eq!(ra.metrics.plan_hits, 1);
        assert_eq!(rb.metrics.plan_hits, 1);
        assert_eq!(ra.outputs[0].dims, vec![2, 3]);
        assert_eq!(rb.outputs[0].dims, vec![5, 7]);
    }

    #[test]
    fn executes_mlp_with_library_gemm() {
        let mut b = Builder::new("mlp");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(8), Dim::Fixed(4)]);
        let bias = b.param(DType::F32, vec![Dim::Fixed(4)]);
        let h = b.dot(x, w).unwrap();
        let bb = b.broadcast_row_like(bias, h).unwrap();
        let a = b.add(h, bb).unwrap();
        let r = b.unary(UnKind::Gelu, a);
        let m = b.finish(vec![r]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let mut rng = Prng::new(7);
        for n in [3usize, 8, 17] {
            let x = Tensor::f32(&[n, 8], rng.fill_f32(n * 8, 1.0));
            let w = Tensor::f32(&[8, 4], rng.fill_f32(32, 0.5));
            let bias = Tensor::f32(&[4], rng.fill_f32(4, 0.5));
            let got = exec.run(&prog, &[x.clone(), w.clone(), bias.clone()]).unwrap();
            let want = eval_module(&prog.module, &[x, w, bias]).unwrap();
            assert!(got.outputs[0].allclose(&want.outputs[0], 1e-4, 1e-4).unwrap());
            assert_eq!(got.metrics.lib_calls, 1);
            assert_eq!(got.metrics.mem_kernels, 1, "bias+gelu fused into one kernel");
        }
    }

    #[test]
    fn mlp_replay_with_gemm_bit_matches() {
        // GEMM -> fused-kernel chaining through device-resident buffers
        // (zero-padded GEMM output consumed in place when buckets align).
        let mut b = Builder::new("mlp");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(8), Dim::Fixed(4)]);
        let bias = b.param(DType::F32, vec![Dim::Fixed(4)]);
        let h = b.dot(x, w).unwrap();
        let bb = b.broadcast_row_like(bias, h).unwrap();
        let a = b.add(h, bb).unwrap();
        let r = b.unary(UnKind::Gelu, a);
        let m = b.finish(vec![r]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut cached = executor();
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(3);
        let w = Tensor::f32(&[8, 4], rng.fill_f32(32, 0.5));
        let bias = Tensor::f32(&[4], rng.fill_f32(4, 0.5));
        for n in [5usize, 5, 5, 9, 5] {
            let x = Tensor::f32(&[n, 8], rng.fill_f32(n * 8, 1.0));
            let a = cached.run(&prog, &[x.clone(), w.clone(), bias.clone()]).unwrap();
            let b2 = plain.run(&prog, &[x, w.clone(), bias.clone()]).unwrap();
            assert_eq!(a.outputs, b2.outputs, "GEMM replay diverged at n={n}");
        }
        assert!(cached.plan_stats.hits >= 3);
    }

    #[test]
    fn dynamic_slice_and_unique_pipeline() {
        // Sparse-workload shape: unique produces a data-dependent length
        // consumed by a gather.
        let mut b = Builder::new("sparse");
        let n = b.dyn_dim("n", 0, 0);
        let ids = b.param(DType::I64, vec![n]);
        let table = b.param(DType::F32, vec![Dim::Fixed(16), Dim::Fixed(4)]);
        let u = b.unique(ids).unwrap();
        let g = b.gather(table, u, 0).unwrap();
        let t = b.unary(UnKind::Tanh, g);
        let m = b.finish(vec![t]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let ids_t = Tensor::i64(&[7], vec![3, 1, 3, 2, 1, 3, 9]);
        let mut table_v = vec![0f32; 64];
        for (i, v) in table_v.iter_mut().enumerate() {
            *v = i as f32 * 0.01;
        }
        let table_t = Tensor::f32(&[16, 4], table_v);
        let got = exec.run(&prog, &[ids_t.clone(), table_t.clone()]).unwrap();
        let want = eval_module(&prog.module, &[ids_t, table_t]).unwrap();
        assert!(got.outputs[0].allclose(&want.outputs[0], 1e-5, 1e-5).unwrap());
        assert_eq!(got.outputs[0].dims, vec![4, 4], "4 unique ids");
    }

    #[test]
    fn unique_suffix_never_served_stale() {
        // Two requests with identical shapes but different id *contents*:
        // the data-dependent suffix must be re-interpreted per request, so
        // the second run cannot inherit the first run's unique count.
        let mut b = Builder::new("sparse");
        let n = b.dyn_dim("n", 0, 0);
        let ids = b.param(DType::I64, vec![n]);
        let table = b.param(DType::F32, vec![Dim::Fixed(16), Dim::Fixed(4)]);
        let u = b.unique(ids).unwrap();
        let g = b.gather(table, u, 0).unwrap();
        let t = b.unary(UnKind::Tanh, g);
        let m = b.finish(vec![t]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let table_t = Tensor::f32(&[16, 4], (0..64).map(|i| i as f32 * 0.01).collect());
        // 4 unique ids.
        let first = Tensor::i64(&[7], vec![3, 1, 3, 2, 1, 3, 9]);
        // Same shape, 2 unique ids.
        let second = Tensor::i64(&[7], vec![5, 5, 5, 5, 5, 8, 8]);
        let got1 = exec.run(&prog, &[first.clone(), table_t.clone()]).unwrap();
        let got2 = exec.run(&prog, &[second.clone(), table_t.clone()]).unwrap();
        assert_eq!(got1.outputs[0].dims, vec![4, 4]);
        assert_eq!(got2.outputs[0].dims, vec![2, 4], "stale plan suffix served");
        let want2 = eval_module(&prog.module, &[second, table_t]).unwrap();
        assert!(got2.outputs[0].allclose(&want2.outputs[0], 1e-6, 1e-6).unwrap());
    }

    #[test]
    fn host_shape_guard_falls_back_to_interpreter() {
        // DSlice bounds arriving as *parameter contents*: two requests with
        // identical shapes but different bounds must not share a plan.
        let mut b = Builder::new("guard");
        let n = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![n]);
        let st = b.param(DType::I64, vec![Dim::Fixed(1)]);
        let li = b.param(DType::I64, vec![Dim::Fixed(1)]);
        let sr = b.param(DType::I64, vec![Dim::Fixed(1)]);
        let sl = b.dslice(x, st, li, sr).unwrap();
        let t = b.unary(UnKind::Tanh, sl);
        let m = b.finish(vec![t]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let x = Tensor::f32(&[8], (0..8).map(|i| i as f32).collect());
        let run = |exec: &mut Executor, lo: i64, hi: i64| {
            exec.run(
                &prog,
                &[
                    x.clone(),
                    Tensor::i64(&[1], vec![lo]),
                    Tensor::i64(&[1], vec![hi]),
                    Tensor::i64(&[1], vec![1]),
                ],
            )
            .unwrap()
        };
        let a = run(&mut exec, 0, 4);
        assert_eq!(a.outputs[0].dims, vec![4]);
        // Same binding vector, different slice bounds: the parameter guard
        // must reject the cached plan and interpret.
        let b2 = run(&mut exec, 2, 8);
        assert_eq!(b2.outputs[0].dims, vec![6], "guard failed to catch stale bounds");
        assert!(exec.plan_stats.guard_misses >= 1);
        // And the matching request replays fine.
        let c = run(&mut exec, 0, 4);
        assert_eq!(c.outputs[0].dims, vec![4]);
        assert_eq!(c.outputs[0], a.outputs[0]);
    }

    #[test]
    fn metrics_show_fusion_benefit() {
        // Chain of 6 elementwise ops: eager would launch 6 kernels; the
        // program launches 1.
        let mut b = Builder::new("chain");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let mut v = x;
        for _ in 0..3 {
            v = b.unary(UnKind::Tanh, v);
            v = b.add(v, x).unwrap();
        }
        let m = b.finish(vec![v]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();
        let mut exec = executor();
        let x = Tensor::f32(&[100], vec![0.1; 100]);
        let out = exec.run(&prog, &[x.clone()]).unwrap();
        assert_eq!(out.metrics.mem_kernels, 1);
        let eager = eval_module(&prog.module, &[x]).unwrap();
        assert_eq!(eager.launches, 6);
        assert!(out.metrics.mem_bytes < eager.bytes_moved as u64);
    }

    #[test]
    fn static_shapes_with_exact_policy_skip_padding() {
        let mut b = Builder::new("static");
        let x = b.param(DType::F32, vec![Dim::Fixed(10)]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(t, x).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();
        let dev = Arc::new(Device::cpu().unwrap());
        let mut exec = Executor::new(
            dev,
            ExecOptions { policy: BucketPolicy::Exact, ..Default::default() },
        );
        let x = Tensor::f32(&[10], vec![0.5; 10]);
        let out = exec.run(&prog, &[x.clone()]).unwrap();
        assert_eq!(out.metrics.pad_copies, 0, "exact policy needs no pad/crop");
        // A fully static program replays from the second request on.
        let out2 = exec.run(&prog, &[x]).unwrap();
        assert_eq!(out2.metrics.plan_hits, 1);
        assert_eq!(out2.metrics.pad_copies, 0);
        assert_eq!(out.outputs, out2.outputs);
    }

    /// `x·W` (constant weight) followed by a fused activation.
    fn const_weight_prog() -> Program {
        let mut b = Builder::new("wmlp");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.constant(
            Literal::F32((0..32).map(|i| 0.05 * i as f32 - 0.6).collect()),
            &[8, 4],
        );
        let h = b.dot(x, w).unwrap();
        let r = b.unary(UnKind::Gelu, h);
        let m = b.finish(vec![r]);
        let p = plan(&m, &FusionOptions::default());
        generate(m, &p).unwrap()
    }

    #[test]
    fn gemm_weights_upload_once_across_calls_and_replays() {
        let prog = const_weight_prog();
        let mut exec = executor();
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(17);
        let x = Tensor::f32(&[5, 8], rng.fill_f32(40, 1.0));

        let r1 = exec.run(&prog, &[x.clone()]).unwrap();
        assert_eq!(r1.metrics.weight_cache_misses, 1, "first call uploads the weight");
        assert_eq!(r1.metrics.weight_cache_hits, 0);
        assert!(r1.metrics.weight_resident_bytes > 0);

        let r2 = exec.run(&prog, &[x.clone()]).unwrap();
        assert_eq!(r2.metrics.plan_hits, 1);
        assert_eq!(r2.metrics.weight_cache_hits, 1, "replay serves the resident weight");
        assert_eq!(r2.metrics.weight_cache_misses, 0);
        assert!(
            r2.metrics.h2d_bytes < r1.metrics.h2d_bytes,
            "replay h2d {} must drop below first-call h2d {} (weight not re-uploaded)",
            r2.metrics.h2d_bytes,
            r1.metrics.h2d_bytes
        );

        // Bit-exact against the host-path interpreter.
        let p = plain.run(&prog, &[x]).unwrap();
        assert_eq!(r1.outputs, p.outputs);
        assert_eq!(r2.outputs, p.outputs);

        // A different binding records a new plan but reuses the weight.
        let y = Tensor::f32(&[9, 8], rng.fill_f32(72, 1.0));
        let r3 = exec.run(&prog, &[y]).unwrap();
        assert_eq!(r3.metrics.plan_misses, 1);
        assert_eq!(r3.metrics.weight_cache_misses, 0, "weight shared across bindings");
        assert_eq!(r3.metrics.weight_cache_hits, 1);
    }

    #[test]
    fn dev_chained_gemm_replay_bit_matches_host_path() {
        // GEMM -> fused tanh -> GEMM: on replay the second GEMM consumes
        // the fused kernel's device-resident (garbage-padded) output
        // through the library's on-device bucket adapter, with both
        // weights served from the cache.
        let mut b = Builder::new("chain");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w1 = b.constant(
            Literal::F32((0..64).map(|i| 0.03 * i as f32 - 0.9).collect()),
            &[8, 8],
        );
        let w2 = b.constant(
            Literal::F32((0..32).map(|i| 0.4 - 0.02 * i as f32).collect()),
            &[8, 4],
        );
        let h = b.dot(x, w1).unwrap();
        let t = b.unary(UnKind::Tanh, h);
        let z = b.dot(t, w2).unwrap();
        let m = b.finish(vec![z]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut cached = executor();
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(23);
        for n in [5usize, 5, 5, 11, 5] {
            let x = Tensor::f32(&[n, 8], rng.fill_f32(n * 8, 1.0));
            let a = cached.run(&prog, &[x.clone()]).unwrap();
            let b2 = plain.run(&prog, &[x]).unwrap();
            assert_eq!(a.outputs, b2.outputs, "dev-chained GEMM diverged at n={n}");
        }
        assert!(cached.plan_stats.hits >= 3);
        assert!(cached.library.stats.weight_hits > 0);
    }

    #[test]
    fn weight_cache_follows_plan_cache_eviction() {
        // Zero weight budget: entries live exactly as long as some
        // installed plan pins them.
        let prog_w = const_weight_prog();
        let prog_plain = softmax_prog();
        let mut exec = executor();
        exec.max_plans = 1;
        let x = Tensor::f32(&[4, 8], vec![0.3; 32]);

        let r1 = exec.run(&prog_w, &[x.clone()]).unwrap();
        assert_eq!(r1.metrics.weight_cache_misses, 1);
        // Tighten the budget only once the entry is pinned by the
        // installed plan: pinned entries survive every enforcement point.
        exec.library.set_max_weight_bytes(0);
        assert!(
            exec.library.weight_resident_bytes() > 0,
            "pinned weight survives a zero budget"
        );

        // Another program's plan displaces the FIFO entry; the unpinned
        // weight is evicted immediately under the zero budget.
        exec.run(&prog_plain, &[Tensor::f32(&[2, 3], vec![0.1; 6])]).unwrap();
        assert_eq!(exec.library.weight_resident_bytes(), 0, "unpinned weight evicted");
        assert_eq!(exec.library.weight_evictions(), 1);

        // Re-running re-records, re-uploads, and stays correct.
        let r2 = exec.run(&prog_w, &[x]).unwrap();
        assert_eq!(r2.metrics.weight_cache_misses, 1);
        assert_eq!(r1.outputs, r2.outputs);
    }

    #[test]
    fn weight_cache_retains_entries_within_budget_across_plan_eviction() {
        // Default (unbounded) budget: dropping the plan keeps the weight
        // resident, and the re-recorded plan hits the cache.
        let prog_w = const_weight_prog();
        let prog_plain = softmax_prog();
        let mut exec = executor();
        exec.max_plans = 1;
        let x = Tensor::f32(&[4, 8], vec![0.3; 32]);

        exec.run(&prog_w, &[x.clone()]).unwrap();
        exec.run(&prog_plain, &[Tensor::f32(&[2, 3], vec![0.1; 6])]).unwrap();
        assert!(exec.library.weight_resident_bytes() > 0, "weight retained");

        let r = exec.run(&prog_w, &[x]).unwrap();
        assert_eq!(r.metrics.weight_cache_misses, 0, "retained weight served");
        assert_eq!(r.metrics.weight_cache_hits, 1);
    }

    #[test]
    fn replay_oom_demotes_to_interpreter_then_recovers() {
        use crate::runtime::faults::FaultPlan;
        // Two injected device-OOM fires: the replay tier's arena acquire
        // fails, the request demotes to the interpret tier, outputs stay
        // bit-identical, and once the schedule is exhausted replay resumes.
        let plan = Arc::new(FaultPlan::parse("seed=4,oom=1000:2").unwrap());
        let dev = Arc::new(Device::cpu_with_faults(Some(plan)).unwrap());
        let mut exec = Executor::new(dev, ExecOptions::default());
        let prog = softmax_prog();
        let input = Tensor::f32(&[4, 8], vec![0.25; 32]);

        let first = exec.run(&prog, &[input.clone()]).unwrap();
        assert_eq!(first.metrics.plan_misses, 1, "record run never touches the arena");
        assert_eq!(first.metrics.demotions, 0);

        let faulted = exec.run(&prog, &[input.clone()]).unwrap();
        assert_eq!(faulted.metrics.demotions, 1, "failed replay demotes");
        assert_eq!(faulted.metrics.plan_hits, 0);
        assert_eq!(faulted.outputs, first.outputs, "demoted path stays bit-identical");
        assert_eq!(
            exec.pool.device.resident_bytes(),
            0,
            "failed replay must not leak arena accounting"
        );

        // One more fire left in the schedule, then clean replays.
        let faulted2 = exec.run(&prog, &[input.clone()]).unwrap();
        assert_eq!(faulted2.metrics.demotions, 1);
        let clean = exec.run(&prog, &[input]).unwrap();
        assert_eq!(clean.metrics.demotions, 0);
        assert_eq!(clean.metrics.plan_hits, 1, "exhausted schedule lets replay resume");
        assert_eq!(clean.outputs, first.outputs);
    }

    #[test]
    fn compile_failures_retry_then_fall_back_to_the_reference_path() {
        use crate::runtime::faults::FaultPlan;
        // Every compile fails: the ladder retries with backoff, then serves
        // the request from the host reference interpreter.
        let plan = Arc::new(FaultPlan::parse("seed=6,compile=1000").unwrap());
        let dev = Arc::new(Device::cpu_with_faults(Some(plan)).unwrap());
        let mut exec = Executor::new(dev, ExecOptions::default());
        let prog = softmax_prog();
        let input = Tensor::f32(&[3, 5], vec![0.5; 15]);

        let out = exec.run(&prog, &[input.clone()]).unwrap();
        assert_eq!(out.metrics.retries, 3, "capped backoff before demoting");
        assert_eq!(out.metrics.demotions, 1, "reference fallback is a demotion");
        let want = eval_module(&prog.module, &[input.clone()]).unwrap();
        assert_eq!(out.outputs, want.outputs, "bottom rung IS the reference path");

        // A transient failure (limit 1) is absorbed by a single retry.
        let plan = Arc::new(FaultPlan::parse("seed=6,compile=1000:1").unwrap());
        let dev = Arc::new(Device::cpu_with_faults(Some(plan)).unwrap());
        let mut exec = Executor::new(dev, ExecOptions::default());
        let out = exec.run(&prog, &[input]).unwrap();
        assert_eq!(out.metrics.retries, 1);
        assert_eq!(out.metrics.demotions, 0, "retry recovered without demoting");
    }

    fn decode_prog() -> Program {
        let g = crate::workloads::decode::graph();
        let m = crate::bridge::lower(&g).unwrap();
        let m = crate::passes::optimize(&m).unwrap();
        let p = plan(&m, &FusionOptions::default());
        generate(m, &p).unwrap()
    }

    #[test]
    fn decode_loop_replays_one_plan_family_per_bucket() {
        let prog = decode_prog();
        let spec = crate::workloads::decode::spec();
        let dev = Arc::new(Device::cpu().unwrap());
        let mut exec = Executor::new(
            dev,
            ExecOptions { policy: BucketPolicy::MultipleOf(16), ..Default::default() },
        );
        // 3 prompt + 17 generated = 20 steps: 16 in the first bucket, one
        // rollover, 4 in the second.
        let out = exec.run_decode(&prog, &spec, &[1, 2, 3], 17).unwrap();
        assert_eq!(out.steps, 20);
        assert_eq!(out.generated.len(), 17);
        assert_eq!(out.step_probs.len(), 20);
        assert_eq!(out.metrics.decode_requests, 1);
        assert_eq!(out.metrics.decode_steps, 20);
        assert_eq!(out.metrics.kv_rollovers, 1, "20 steps cross one bucket edge");
        assert_eq!(out.metrics.plan_misses, 2, "exactly one record per bucket family");
        assert_eq!(out.metrics.plan_hits, 18, "every other step replays");
        let vocab = crate::workloads::decode::VOCAB as i64;
        assert!(out.generated.iter().all(|&t| (0..vocab).contains(&t)));
        for p in &out.step_probs {
            assert_eq!(p.dims, vec![1, crate::workloads::decode::VOCAB]);
        }
        // Slab accounting: released on exit, high water saw the rollover.
        assert_eq!(exec.pool.device.kv_resident_bytes(), 0, "request exit releases its slab");
        assert!(exec.pool.device.kv_high_water_bytes() >= spec.slab_bytes(32));
        assert_eq!(out.metrics.kv_resident_bytes, exec.pool.device.kv_high_water_bytes());
    }

    #[test]
    fn decode_slab_oom_demotes_to_host_residency() {
        use crate::runtime::faults::FaultPlan;
        // The one injected OOM fires on the slab acquire: the request
        // keeps decoding with host-resident slabs (a demotion, not a
        // failure) and produces the same tokens as a fault-free run.
        let prog = decode_prog();
        let spec = crate::workloads::decode::spec();
        let faulted = Arc::new(FaultPlan::parse("seed=3,oom=1000:1").unwrap());
        let dev = Arc::new(Device::cpu_with_faults(Some(faulted)).unwrap());
        let opts = ExecOptions { policy: BucketPolicy::MultipleOf(16), ..Default::default() };
        let mut exec = Executor::new(dev, opts.clone());
        let out = exec.run_decode(&prog, &spec, &[5, 9], 6).unwrap();
        assert!(out.metrics.demotions >= 1, "slab OOM must demote");
        assert_eq!(exec.pool.device.kv_resident_bytes(), 0);
        assert_eq!(exec.pool.device.kv_high_water_bytes(), 0, "demoted slab never resident");

        let mut clean = Executor::new(Arc::new(Device::cpu().unwrap()), opts);
        let want = clean.run_decode(&prog, &spec, &[5, 9], 6).unwrap();
        assert_eq!(out.generated, want.generated, "residency never changes the numerics");
        assert_eq!(out.step_probs.len(), want.step_probs.len());
        for (a, b) in out.step_probs.iter().zip(&want.step_probs) {
            assert_eq!(a, b, "demoted decode stays bit-identical");
        }
    }
}
