//! The program executor: walks the compile-time-generated runtime flow.
//!
//! Per request: bind input shapes (checking constraints), then execute the
//! flat step array — host ops on the host, fused kernels through the
//! bucket-keyed executable cache, GEMMs through the library, deallocations
//! where liveness placed them. No graph interpretation happens here; this
//! is the "generated runtime flow works more efficiently" half of the
//! paper's Table 2 CPU-time comparison (the other half is `crate::vm`).

use crate::codegen::{BucketPolicy, KernelCache};
use crate::dhlo::Op;
use crate::library::GemmLibrary;
use crate::program::{Program, Step};
use crate::runtime::buffers::BufferPool;
use crate::runtime::metrics::RunMetrics;
use crate::runtime::pjrt::Device;
use crate::runtime::reference::eval_op;
use crate::runtime::shape_env::SymEnv;
use crate::runtime::tensor::{strides_of, Data, Tensor};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

/// Executor options.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    pub policy: BucketPolicy,
    /// Use the pooled (cached) allocator for marshalling buffers.
    pub pooled_buffers: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { policy: BucketPolicy::NextPow2, pooled_buffers: true }
    }
}

/// Stateful executor: owns the kernel cache, library, and buffer pool, so
/// the caches persist across requests (that is the whole point).
pub struct Executor {
    pub cache: KernelCache,
    pub library: GemmLibrary,
    pub pool: BufferPool,
    pub opts: ExecOptions,
}

pub struct ExecOutput {
    pub outputs: Vec<Tensor>,
    pub metrics: RunMetrics,
}

impl Executor {
    pub fn new(device: Rc<Device>, opts: ExecOptions) -> Self {
        Executor {
            cache: KernelCache::new(device.clone(), opts.policy),
            library: GemmLibrary::new(device),
            pool: BufferPool::new(),
            opts,
        }
    }

    /// Execute a program against concrete inputs.
    pub fn run(&mut self, prog: &Program, inputs: &[Tensor]) -> Result<ExecOutput> {
        let t_start = Instant::now();
        let m = &prog.module;
        let mut metrics = RunMetrics::default();
        let mut env = SymEnv::new();
        env.bind_params(m, inputs)?;

        let mut vals: Vec<Option<Rc<Tensor>>> = vec![None; m.instrs.len()];
        // Materialize params and constants.
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => vals[id] = Some(Rc::new(inputs[*index].clone())),
                Op::Const { lit, dims } => {
                    vals[id] = Some(Rc::new(Tensor::from_literal(lit, dims)))
                }
                _ => {}
            }
        }

        let lib_before = self.library.stats.clone();
        let cache_before = (self.cache.stats.misses, self.cache.stats.compile_time);
        let pool_before = self.pool.stats.clone();

        for step in &prog.steps {
            match step {
                Step::EvalHost { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &vals[..])?;
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| vals[o].as_deref().unwrap()).collect();
                    let t = eval_op(&ins.op, &operands, &out_dims, ins.ty.dtype)
                        .with_context(|| format!("host op %{value}"))?;
                    metrics.host_ops += 1;
                    vals[*value] = Some(Rc::new(t));
                }
                Step::Bitcast { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &vals[..])?;
                    let src = vals[ins.operands[0]].as_deref().unwrap().clone();
                    metrics.bitcasts += 1;
                    vals[*value] = Some(Rc::new(src.with_dims(&out_dims)?));
                }
                Step::LaunchOp { value } => {
                    let ins = &m.instrs[*value];
                    // Data-dependent outputs (Unique) resolve their own
                    // extent; everything else resolves from the shape env.
                    let out_dims = if matches!(ins.op, Op::Unique) {
                        vec![]
                    } else {
                        env.resolve_dims(m, &ins.ty.dims, &vals[..])?
                    };
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| vals[o].as_deref().unwrap()).collect();
                    for o in &operands {
                        metrics.mem_bytes += o.byte_size() as u64;
                    }
                    let tk = Instant::now();
                    let t = eval_op(&ins.op, &operands, &out_dims, ins.ty.dtype)
                        .with_context(|| format!("singleton kernel %{value}"))?;
                    metrics.kernel_time += tk.elapsed();
                    metrics.mem_kernels += 1;
                    metrics.mem_bytes += t.byte_size() as u64;
                    if matches!(ins.op, Op::Unique) {
                        env.set_datadep(m, *value, t.dims[0] as i64);
                    }
                    vals[*value] = Some(Rc::new(t));
                }
                Step::LibraryCall { value } => {
                    let ins = &m.instrs[*value];
                    let a = vals[ins.operands[0]].as_deref().unwrap();
                    let b = vals[ins.operands[1]].as_deref().unwrap();
                    metrics.lib_bytes += (a.byte_size() + b.byte_size()) as u64;
                    let build0 = self.library.stats.build_time;
                    let exec0 = self.library.stats.exec_time;
                    let t = self.library.matmul(a, b)?;
                    metrics.lib_time += self.library.stats.exec_time - exec0;
                    // On-demand library builds are one-time compile cost
                    // (vendor libraries ship pre-built).
                    metrics.compile_time += self.library.stats.build_time - build0;
                    metrics.lib_calls += 1;
                    metrics.lib_bytes += t.byte_size() as u64;
                    vals[*value] = Some(Rc::new(t));
                }
                Step::LaunchFused { idx } => {
                    let fl = &prog.fused[*idx];
                    // 1. Resolve actual extents of the group's symbols.
                    let mut actual: HashMap<crate::shape::SymId, usize> =
                        HashMap::with_capacity(fl.syms.len());
                    for &s in &fl.syms {
                        let v = env.resolve_dim(m, crate::shape::Dim::Sym(s), &vals[..])?;
                        actual.insert(s, v);
                    }
                    // 2. Cache lookup / compile.
                    let (kernel, _buckets) =
                        self.cache.get_or_compile(m, &fl.group, &fl.sig, &actual)?;
                    // 3. Marshal inputs: pad to bucket extents when
                    //    needed; aligned inputs are passed by reference
                    //    (no host copy before literal marshalling).
                    let spec = &kernel.spec;
                    let mut owned: Vec<Tensor> =
                        Vec::with_capacity(spec.extent_locals.len() + 2);
                    let mut arg_ix: Vec<isize> = Vec::with_capacity(
                        fl.inputs.len() + spec.extent_locals.len(),
                    );
                    for (i, &v) in fl.inputs.iter().enumerate() {
                        let src = vals[v].as_deref().unwrap();
                        if src.dims == spec.input_dims[i] {
                            arg_ix.push(-(v as isize) - 1);
                            metrics.mem_bytes += src.byte_size() as u64;
                        } else {
                            metrics.pad_copies += 1;
                            let padded = pad_box(
                                src,
                                &spec.input_dims[i],
                                if self.opts.pooled_buffers { Some(&mut self.pool) } else { None },
                            )?;
                            // The kernel reads the full bucket-shaped
                            // buffer: padding is real off-chip traffic
                            // (the modeled cost of shape-adaptive
                            // bucketing, and the source of the Fig. 4
                            // static/dynamic gap).
                            metrics.mem_bytes += padded.byte_size() as u64;
                            arg_ix.push(owned.len() as isize);
                            owned.push(padded);
                        }
                    }
                    for &li in &spec.extent_locals {
                        let v = actual[&fl.syms[li]];
                        arg_ix.push(owned.len() as isize);
                        owned.push(Tensor::i32(&[], vec![v as i32]));
                    }
                    let args: Vec<&Tensor> = arg_ix
                        .iter()
                        .map(|&ix| {
                            if ix >= 0 {
                                &owned[ix as usize]
                            } else {
                                vals[(-ix - 1) as usize].as_deref().unwrap()
                            }
                        })
                        .collect();
                    // 4. Launch.
                    let tk = Instant::now();
                    let out =
                        kernel.exe.run(&args, &spec.out_dims, spec.out_dtype).with_context(
                            || format!("launching fused kernel {}", spec.name),
                        )?;
                    metrics.kernel_time += tk.elapsed();
                    metrics.mem_kernels += 1;
                    drop(args);
                    // Return pooled pad buffers.
                    if self.opts.pooled_buffers {
                        for a in owned {
                            if let Data::F32(v) = a.data {
                                if v.capacity() > 0 {
                                    self.pool.free_f32(v);
                                }
                            }
                        }
                    }
                    // The kernel writes the bucket-shaped output.
                    metrics.mem_bytes += out.byte_size() as u64;
                    // 5. Crop to actual extents.
                    let actual_out =
                        env.resolve_dims(m, &m.ty(fl.root).dims, &vals[..])?;
                    let out = if out.dims == actual_out {
                        out
                    } else {
                        metrics.pad_copies += 1;
                        crop_box(&out, &actual_out)?
                    };
                    vals[fl.root] = Some(Rc::new(out));
                }
                Step::Dealloc { value } => {
                    // Liveness-placed release; Rc drop returns memory.
                    vals[*value] = None;
                }
            }
        }

        let outputs: Vec<Tensor> = m
            .outputs
            .iter()
            .map(|&o| {
                vals[o]
                    .as_deref()
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("output %{o} was deallocated"))
            })
            .collect::<Result<_>>()?;

        // Fold in component-level stats for this run.
        metrics.flops = self.library.stats.flops - lib_before.flops;
        metrics.compile_events = self.cache.stats.misses - cache_before.0;
        metrics.compile_time = self.cache.stats.compile_time - cache_before.1;
        metrics.allocs = self.pool.stats.allocs - pool_before.allocs;
        metrics.pool_hits = self.pool.stats.pool_hits - pool_before.pool_hits;
        metrics.total_time = t_start.elapsed();
        Ok(ExecOutput { outputs, metrics })
    }
}

/// Copy `src` into a fresh tensor of `bucket_dims` (each `>= src.dims[i]`),
/// filling the tail with zeros. The valid data occupies the prefix box.
pub fn pad_box(src: &Tensor, bucket_dims: &[usize], pool: Option<&mut BufferPool>) -> Result<Tensor> {
    anyhow::ensure!(src.rank() == bucket_dims.len(), "pad_box rank mismatch");
    let n: usize = bucket_dims.iter().product();
    match &src.data {
        Data::F32(v) => {
            let mut out = match pool {
                Some(p) => p.alloc_f32(n, 0.0),
                None => vec![0.0; n],
            };
            copy_box(v, &src.dims, &mut out, bucket_dims);
            Ok(Tensor::f32(bucket_dims, out))
        }
        Data::I64(v) => {
            let mut out = vec![0i64; n];
            copy_box(v, &src.dims, &mut out, bucket_dims);
            Ok(Tensor::i64(bucket_dims, out))
        }
        Data::I32(v) => {
            let mut out = vec![0i32; n];
            copy_box(v, &src.dims, &mut out, bucket_dims);
            Ok(Tensor::i32(bucket_dims, out))
        }
        Data::Pred(_) => anyhow::bail!("pred pad unsupported"),
    }
}

/// Extract the prefix box `actual_dims` from a bucket-shaped tensor.
pub fn crop_box(src: &Tensor, actual_dims: &[usize]) -> Result<Tensor> {
    anyhow::ensure!(src.rank() == actual_dims.len(), "crop_box rank mismatch");
    let n: usize = actual_dims.iter().product();
    match &src.data {
        Data::F32(v) => {
            let mut out = vec![0.0f32; n];
            copy_box_rev(v, &src.dims, &mut out, actual_dims);
            Ok(Tensor::f32(actual_dims, out))
        }
        Data::I64(v) => {
            let mut out = vec![0i64; n];
            copy_box_rev(v, &src.dims, &mut out, actual_dims);
            Ok(Tensor::i64(actual_dims, out))
        }
        Data::I32(v) => {
            let mut out = vec![0i32; n];
            copy_box_rev(v, &src.dims, &mut out, actual_dims);
            Ok(Tensor::i32(actual_dims, out))
        }
        Data::Pred(_) => anyhow::bail!("pred crop unsupported"),
    }
}

/// Copy the `src_dims` box of `src` into the top-left corner of a
/// `dst_dims` buffer. Row-run optimized: contiguous over the last axis.
fn copy_box<T: Copy>(src: &[T], src_dims: &[usize], dst: &mut [T], dst_dims: &[usize]) {
    if src_dims.is_empty() {
        dst[0] = src[0];
        return;
    }
    let row = *src_dims.last().unwrap();
    let rows: usize = src_dims[..src_dims.len() - 1].iter().product();
    let src_strides = strides_of(src_dims);
    let dst_strides = strides_of(dst_dims);
    for r in 0..rows {
        // Unravel row index over the leading dims.
        let mut rem = r;
        let mut src_off = 0usize;
        let mut dst_off = 0usize;
        for i in (0..src_dims.len() - 1).rev() {
            let c = rem % src_dims[i];
            rem /= src_dims[i];
            src_off += c * src_strides[i];
            dst_off += c * dst_strides[i];
        }
        dst[dst_off..dst_off + row].copy_from_slice(&src[src_off..src_off + row]);
    }
}

/// Copy the top-left `dst_dims` box of `src` (shaped `src_dims`) out.
fn copy_box_rev<T: Copy>(src: &[T], src_dims: &[usize], dst: &mut [T], dst_dims: &[usize]) {
    if dst_dims.is_empty() {
        dst[0] = src[0];
        return;
    }
    let row = *dst_dims.last().unwrap();
    let rows: usize = dst_dims[..dst_dims.len() - 1].iter().product();
    let src_strides = strides_of(src_dims);
    let dst_strides = strides_of(dst_dims);
    for r in 0..rows {
        let mut rem = r;
        let mut src_off = 0usize;
        let mut dst_off = 0usize;
        for i in (0..dst_dims.len() - 1).rev() {
            let c = rem % dst_dims[i];
            rem /= dst_dims[i];
            src_off += c * src_strides[i];
            dst_off += c * dst_strides[i];
        }
        dst[dst_off..dst_off + row].copy_from_slice(&src[src_off..src_off + row]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::fusion::{plan, FusionOptions};
    use crate::program::generate;
    use crate::runtime::reference::eval_module;
    use crate::shape::Dim;
    use crate::util::prng::Prng;

    fn executor() -> Executor {
        let dev = Rc::new(Device::cpu().unwrap());
        Executor::new(dev, ExecOptions::default())
    }

    #[test]
    fn pad_and_crop_roundtrip() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_box(&t, &[4, 4], None).unwrap();
        assert_eq!(p.dims, vec![4, 4]);
        assert_eq!(p.as_f32().unwrap()[0..3], [1., 2., 3.]);
        assert_eq!(p.as_f32().unwrap()[3], 0.0);
        assert_eq!(p.as_f32().unwrap()[4..7], [4., 5., 6.]);
        let c = crop_box(&p, &[2, 3]).unwrap();
        assert_eq!(c, t);
    }

    #[test]
    fn executes_softmax_against_reference_over_shape_stream() {
        let mut b = Builder::new("softmax");
        let s = b.dyn_dim("rows", 0, 0);
        let c = b.dyn_dim("cols", 0, 1);
        let x = b.param(DType::F32, vec![s, c]);
        let y = b.softmax_last(x).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let mut rng = Prng::new(42);
        for (rows, cols) in [(2usize, 3usize), (5, 7), (1, 16), (3, 3), (4, 9)] {
            let data = rng.fill_f32(rows * cols, 2.0);
            let input = Tensor::f32(&[rows, cols], data);
            let got = exec.run(&prog, &[input.clone()]).unwrap();
            let want = eval_module(&prog.module, &[input]).unwrap();
            assert!(
                got.outputs[0].allclose(&want.outputs[0], 1e-5, 1e-5).unwrap(),
                "mismatch at {rows}x{cols}"
            );
        }
        // Re-running the same shape stream triggers zero new compiles:
        // every (pattern, bucket) is already cached.
        let misses_after_first_pass = exec.cache.stats.misses;
        for (rows, cols) in [(2usize, 3usize), (5, 7), (1, 16), (3, 3), (4, 9)] {
            let input = Tensor::f32(&[rows, cols], rng.fill_f32(rows * cols, 2.0));
            exec.run(&prog, &[input]).unwrap();
        }
        assert_eq!(exec.cache.stats.misses, misses_after_first_pass);
        assert!(exec.cache.stats.hits > 0, "bucket reuse must kick in");
    }

    #[test]
    fn executes_mlp_with_library_gemm() {
        let mut b = Builder::new("mlp");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(8), Dim::Fixed(4)]);
        let bias = b.param(DType::F32, vec![Dim::Fixed(4)]);
        let h = b.dot(x, w).unwrap();
        let bb = b.broadcast_row_like(bias, h).unwrap();
        let a = b.add(h, bb).unwrap();
        let r = b.unary(UnKind::Gelu, a);
        let m = b.finish(vec![r]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let mut rng = Prng::new(7);
        for n in [3usize, 8, 17] {
            let x = Tensor::f32(&[n, 8], rng.fill_f32(n * 8, 1.0));
            let w = Tensor::f32(&[8, 4], rng.fill_f32(32, 0.5));
            let bias = Tensor::f32(&[4], rng.fill_f32(4, 0.5));
            let got = exec.run(&prog, &[x.clone(), w.clone(), bias.clone()]).unwrap();
            let want = eval_module(&prog.module, &[x, w, bias]).unwrap();
            assert!(got.outputs[0].allclose(&want.outputs[0], 1e-4, 1e-4).unwrap());
            assert_eq!(got.metrics.lib_calls, 1);
            assert_eq!(got.metrics.mem_kernels, 1, "bias+gelu fused into one kernel");
        }
    }

    #[test]
    fn dynamic_slice_and_unique_pipeline() {
        // Sparse-workload shape: unique produces a data-dependent length
        // consumed by a gather.
        let mut b = Builder::new("sparse");
        let n = b.dyn_dim("n", 0, 0);
        let ids = b.param(DType::I64, vec![n]);
        let table = b.param(DType::F32, vec![Dim::Fixed(16), Dim::Fixed(4)]);
        let u = b.unique(ids).unwrap();
        let g = b.gather(table, u, 0).unwrap();
        let t = b.unary(UnKind::Tanh, g);
        let m = b.finish(vec![t]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let mut exec = executor();
        let ids_t = Tensor::i64(&[7], vec![3, 1, 3, 2, 1, 3, 9]);
        let mut table_v = vec![0f32; 64];
        for (i, v) in table_v.iter_mut().enumerate() {
            *v = i as f32 * 0.01;
        }
        let table_t = Tensor::f32(&[16, 4], table_v);
        let got = exec.run(&prog, &[ids_t.clone(), table_t.clone()]).unwrap();
        let want = eval_module(&prog.module, &[ids_t, table_t]).unwrap();
        assert!(got.outputs[0].allclose(&want.outputs[0], 1e-5, 1e-5).unwrap());
        assert_eq!(got.outputs[0].dims, vec![4, 4], "4 unique ids");
    }

    #[test]
    fn metrics_show_fusion_benefit() {
        // Chain of 6 elementwise ops: eager would launch 6 kernels; the
        // program launches 1.
        let mut b = Builder::new("chain");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let mut v = x;
        for _ in 0..3 {
            v = b.unary(UnKind::Tanh, v);
            v = b.add(v, x).unwrap();
        }
        let m = b.finish(vec![v]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();
        let mut exec = executor();
        let x = Tensor::f32(&[100], vec![0.1; 100]);
        let out = exec.run(&prog, &[x.clone()]).unwrap();
        assert_eq!(out.metrics.mem_kernels, 1);
        let eager = eval_module(&prog.module, &[x]).unwrap();
        assert_eq!(eager.launches, 6);
        assert!(out.metrics.mem_bytes < eager.bytes_moved as u64);
    }

    #[test]
    fn static_shapes_with_exact_policy_skip_padding() {
        let mut b = Builder::new("static");
        let x = b.param(DType::F32, vec![Dim::Fixed(10)]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(t, x).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();
        let dev = Rc::new(Device::cpu().unwrap());
        let mut exec = Executor::new(
            dev,
            ExecOptions { policy: BucketPolicy::Exact, ..Default::default() },
        );
        let x = Tensor::f32(&[10], vec![0.5; 10]);
        let out = exec.run(&prog, &[x]).unwrap();
        assert_eq!(out.metrics.pad_copies, 0, "exact policy needs no pad/crop");
    }
}
