//! Per-request KV cache for autoregressive decode.
//!
//! The hardest dynamic-shape scenario in the paper's lineage is the decode
//! *loop*: sequence length grows by one per step, so a naive
//! implementation re-binds (and re-records) a new shape every iteration.
//! The cache sidesteps that by storing each request's keys/values in
//! **bucket-sized slabs**: host/device buffers whose leading extent is the
//! bucket of the current sequence length under the executor's
//! [`BucketPolicy`]. Appends write in place and the slab is passed to the
//! decode graph at its *padded capacity* `C`, so every step inside a
//! bucket binds the identical symbol vector and replays the same
//! [`LaunchPlan`](crate::runtime::plan::LaunchPlan) family. Only when the
//! sequence outgrows `C` does the slab **roll over** to the next bucket —
//! costing exactly one new plan record.
//!
//! Pad lanes stay bit-exact for free: the step graph adds an additive mask
//! (`0.0` on valid lanes, [`MASK_NEG`] on empty ones) to the attention
//! energies, and `exp(x - max)` underflows to exactly `0.0f32` on masked
//! lanes, so softmax weights — and therefore every output — are bitwise
//! identical to an exact-length computation.
//!
//! Slab bytes are accounted in the third residency class of the
//! [`DeviceArena`](crate::runtime::buffers::DeviceArena)
//! (`kv_resident_bytes`): slabs outlive every launch of their request but
//! die when the request exits, unlike per-launch intermediates and
//! process-lifetime GEMM weights. The executor's step-loop driver
//! (`Executor::run_decode`) and the coordinator's iteration-level
//! scheduler (`coordinator::decode`) own acquisition/release.

use crate::codegen::policy::PolicySwitch;
use crate::codegen::BucketPolicy;
use crate::runtime::tensor::{Data, Tensor};
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// Additive attention-mask value for empty (future/pad) lanes. Large
/// enough that `exp(x - max)` underflows to exactly `0.0f32` after the
/// stable-softmax shift, keeping padded softmax bitwise identical to the
/// exact-length computation on valid lanes.
pub const MASK_NEG: f32 = -1e9;

/// Static description of a decode-mode model: what the step graph expects
/// and how tokens embed. Produced by the workload that built the graph
/// (see `workloads::decode::spec`).
#[derive(Debug, Clone, Copy)]
pub struct DecodeSpec {
    /// Transformer layers == number of per-layer KV slab parameters.
    pub layers: usize,
    /// Hidden width `H`; slabs are `[C, 2H]` (keys ++ values columns).
    pub hidden: usize,
    /// Vocabulary size of the `probs` output.
    pub vocab: usize,
    /// Deterministic host-side token embedding: `(token, hidden) -> [H]`.
    pub embed: fn(i64, usize) -> Vec<f32>,
}

impl DecodeSpec {
    /// Bytes of one request's slabs at bucket capacity `c`: per-layer
    /// `[c, 2H]` KV slabs plus the `[c, H]` embedding history, f32.
    pub fn slab_bytes(&self, c: usize) -> u64 {
        ((self.layers * 2 * self.hidden + self.hidden) * c * 4) as u64
    }
}

/// One request's decode state: embedding history + per-layer KV slabs at
/// the current bucket capacity, plus the append cursor.
#[derive(Debug, Clone)]
pub struct KvCache {
    spec: DecodeSpec,
    policy: BucketPolicy,
    /// Live bucket-policy handle: when set, [`grow`](KvCache::grow)
    /// targets the *current* [`Boundaries`](crate::codegen::Boundaries)
    /// (re-read per rollover, so an epoch flip mid-request redirects the
    /// very next rollover); when `None`, the static base policy decides.
    switch: Option<Arc<PolicySwitch>>,
    /// Current bucket capacity `C` (leading extent of every step input).
    capacity: usize,
    /// Valid rows: tokens whose k/v have been appended so far.
    used: usize,
    /// Embedding history `[C, H]` (row `t` = embedding of token `t`).
    x_hist: Vec<f32>,
    /// Per-layer KV slabs `[C, 2H]`, keys in columns `0..H`, values in
    /// `H..2H`.
    slabs: Vec<Vec<f32>>,
    /// Bucket rollovers performed by this cache.
    pub rollovers: u64,
}

impl KvCache {
    pub fn new(spec: DecodeSpec, policy: BucketPolicy) -> KvCache {
        let capacity = policy.bucket(1);
        KvCache {
            spec,
            policy,
            switch: None,
            capacity,
            used: 0,
            x_hist: vec![0.0; capacity * spec.hidden],
            slabs: vec![vec![0.0; capacity * 2 * spec.hidden]; spec.layers],
            rollovers: 0,
        }
    }

    /// Attach the executor's live policy handle so rollovers target the
    /// current adaptive boundaries instead of the static base policy.
    pub fn with_switch(mut self, switch: Arc<PolicySwitch>) -> KvCache {
        self.switch = Some(switch);
        self
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.used
    }

    /// No append slot left: the next step must [`grow`](KvCache::grow)
    /// first (a bucket rollover).
    pub fn full(&self) -> bool {
        self.used == self.capacity
    }

    /// Bytes of this cache's slabs at the current capacity — what the
    /// arena's KV class holds while the request is device-resident.
    pub fn slab_bytes(&self) -> u64 {
        self.spec.slab_bytes(self.capacity)
    }

    /// Roll the slabs over to the next bucket: reallocate at
    /// `bucket(capacity + 1)`, copying live rows and zero-filling the new
    /// tail. The caller re-accounts arena bytes (release old, acquire new)
    /// and pays one plan record on the next step — the new leading extent
    /// is a fresh binding vector.
    pub fn grow(&mut self) {
        let new_cap = match &self.switch {
            Some(sw) => sw.snapshot().1.bucket_any(self.capacity + 1),
            None => self.policy.bucket(self.capacity + 1),
        };
        debug_assert!(new_cap > self.capacity, "bucket policy must grow the capacity");
        let h = self.spec.hidden;
        self.x_hist.resize(new_cap * h, 0.0);
        for slab in &mut self.slabs {
            slab.resize(new_cap * 2 * h, 0.0);
        }
        self.capacity = new_cap;
        self.rollovers += 1;
    }

    /// Build the step inputs for the next token, in the decode graph's
    /// parameter order: `[x_hist, aux, slab_0, .., slab_{L-1}]`, every
    /// tensor at the padded capacity `C` so consecutive steps inside a
    /// bucket bind identically. Writes the token's embedding into the
    /// history at row `used`; `aux` column 0 is the additive mask over
    /// past lanes (`0.0` below `used`, [`MASK_NEG`] from `used` up) and
    /// column 1 one-hot selects the current row.
    pub fn step_inputs(&mut self, token: i64) -> Result<Vec<Tensor>> {
        ensure!(!self.full(), "kv cache full at capacity {}: grow() first", self.capacity);
        let (c, h) = (self.capacity, self.spec.hidden);
        let emb = (self.spec.embed)(token, h);
        ensure!(emb.len() == h, "embed returned {} values, want {h}", emb.len());
        self.x_hist[self.used * h..(self.used + 1) * h].copy_from_slice(&emb);
        let mut aux = vec![0.0f32; c * 2];
        for lane in 0..c {
            aux[lane * 2] = if lane < self.used { 0.0 } else { MASK_NEG };
            aux[lane * 2 + 1] = if lane == self.used { 1.0 } else { 0.0 };
        }
        let mut inputs = Vec::with_capacity(2 + self.spec.layers);
        inputs.push(Tensor::f32(&[c, h], self.x_hist.clone()));
        inputs.push(Tensor::f32(&[c, 2], aux));
        for slab in &self.slabs {
            inputs.push(Tensor::f32(&[c, 2 * h], slab.clone()));
        }
        Ok(inputs)
    }

    /// Append one step's per-layer `[1, 2H]` KV rows (the graph's
    /// `kv_new_*` outputs) in place at row `used`, advancing the cursor.
    pub fn append(&mut self, kv_rows: &[Tensor]) -> Result<()> {
        ensure!(!self.full(), "kv cache full at capacity {}: grow() first", self.capacity);
        ensure!(
            kv_rows.len() == self.spec.layers,
            "append wants {} kv rows, got {}",
            self.spec.layers,
            kv_rows.len()
        );
        let h2 = 2 * self.spec.hidden;
        for (slab, row) in self.slabs.iter_mut().zip(kv_rows) {
            ensure!(row.dims == [1, h2], "kv row dims {:?}, want [1, {h2}]", row.dims);
            let Data::F32(v) = &row.data else {
                bail!("kv row must be f32");
            };
            slab[self.used * h2..(self.used + 1) * h2].copy_from_slice(v);
        }
        self.used += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_spec() -> DecodeSpec {
        fn emb(token: i64, hidden: usize) -> Vec<f32> {
            (0..hidden).map(|i| (token as f32) + i as f32).collect()
        }
        DecodeSpec { layers: 2, hidden: 4, vocab: 8, embed: emb }
    }

    fn kv_row(h: usize, fill: f32) -> Tensor {
        Tensor::f32(&[1, 2 * h], vec![fill; 2 * h])
    }

    #[test]
    fn capacity_follows_bucket_policy() {
        let mut kv = KvCache::new(test_spec(), BucketPolicy::MultipleOf(16));
        assert_eq!(kv.capacity(), 16);
        for step in 0..16 {
            assert!(!kv.full(), "step {step}");
            kv.step_inputs(step as i64).unwrap();
            kv.append(&[kv_row(4, 1.0), kv_row(4, 2.0)]).unwrap();
        }
        assert!(kv.full());
        kv.grow();
        assert_eq!(kv.capacity(), 32);
        assert_eq!(kv.rollovers, 1);
        assert_eq!(kv.used(), 16, "grow keeps live rows");
    }

    #[test]
    fn step_inputs_mask_and_selector() {
        let spec = test_spec();
        let mut kv = KvCache::new(spec, BucketPolicy::MultipleOf(4));
        kv.append(&[kv_row(4, 0.5), kv_row(4, 0.25)]).unwrap(); // one past token
        let inputs = kv.step_inputs(7).unwrap();
        assert_eq!(inputs.len(), 4, "x_hist + aux + one slab per layer");
        assert_eq!(inputs[0].dims, vec![4, 4]);
        assert_eq!(inputs[1].dims, vec![4, 2]);
        assert_eq!(inputs[2].dims, vec![4, 8]);
        let Data::F32(aux) = &inputs[1].data else { panic!("aux dtype") };
        // Lane 0 is the (only) valid past lane; lane 1 is current (masked
        // in the past-attention, selected for the embedding row).
        assert_eq!(aux[0], 0.0);
        assert_eq!(aux[1], 0.0);
        assert_eq!(aux[2], MASK_NEG);
        assert_eq!(aux[3], 1.0);
        assert_eq!(aux[4], MASK_NEG);
        assert_eq!(aux[5], 0.0);
        let Data::F32(xh) = &inputs[0].data else { panic!("x_hist dtype") };
        assert_eq!(&xh[4..8], &[7.0, 8.0, 9.0, 10.0], "embedding written at row used");
        let Data::F32(slab) = &inputs[2].data else { panic!("slab dtype") };
        assert!(slab[..8].iter().all(|&x| x == 0.5), "appended kv row survives");
    }

    #[test]
    fn append_round_trips_through_grow() {
        let mut kv = KvCache::new(test_spec(), BucketPolicy::NextPow2);
        assert_eq!(kv.capacity(), 1);
        kv.append(&[kv_row(4, 1.0), kv_row(4, 1.0)]).unwrap();
        assert!(kv.append(&[kv_row(4, 2.0), kv_row(4, 2.0)]).is_err(), "full slab rejects");
        kv.grow();
        assert_eq!(kv.capacity(), 2);
        kv.append(&[kv_row(4, 2.0), kv_row(4, 2.0)]).unwrap();
        let inputs = kv.step_inputs(0).unwrap();
        // Grow happened mid-stream: both rows must survive in the slab.
        let Data::F32(slab) = &inputs[2].data else { panic!("slab dtype") };
        assert!(slab[..8].iter().all(|&x| x == 1.0));
        assert!(slab[8..16].iter().all(|&x| x == 2.0));
    }

    #[test]
    fn grow_targets_live_boundaries_through_switch() {
        use crate::codegen::Boundaries;
        use crate::shape::SymId;
        let sw = Arc::new(PolicySwitch::new(BucketPolicy::NextPow2));
        let mut kv = KvCache::new(test_spec(), BucketPolicy::NextPow2).with_switch(sw.clone());
        assert_eq!(kv.capacity(), 1);
        let mut cuts = std::collections::BTreeMap::new();
        cuts.insert(SymId(0), vec![5, 12]);
        sw.install(Boundaries { base: BucketPolicy::NextPow2, cuts });
        kv.grow();
        assert_eq!(kv.capacity(), 5, "rollover lands on the live cut");
        kv.grow();
        assert_eq!(kv.capacity(), 12);
        kv.grow();
        assert_eq!(kv.capacity(), 16, "past every cut: base policy");
    }

    #[test]
    fn slab_bytes_track_capacity() {
        let spec = test_spec();
        let mut kv = KvCache::new(spec, BucketPolicy::MultipleOf(8));
        // (2 layers * 2H + H) * C * 4 bytes = (16 + 4) * 8 * 4.
        assert_eq!(kv.slab_bytes(), 640);
        kv.grow();
        assert_eq!(kv.slab_bytes(), 1280);
    }
}
