//! Host tensors: the runtime data representation flowing between kernels.
//!
//! Row-major dense tensors over the DHLO element types. These back (a) the
//! reference interpreter / eager baseline, (b) the host side of PJRT literal
//! marshalling, and (c) the host-resident shape tensors of the dynamic twins.

use crate::dhlo::{DType, Literal};
use anyhow::{bail, ensure, Result};

/// A dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I64(Vec<i64>),
    I32(Vec<i32>),
    Pred(Vec<bool>),
}

impl Tensor {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor { dtype: DType::F32, dims: dims.to_vec(), data: Data::F32(data) }
    }

    pub fn i64(dims: &[usize], data: Vec<i64>) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor { dtype: DType::I64, dims: dims.to_vec(), data: Data::I64(data) }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor { dtype: DType::I32, dims: dims.to_vec(), data: Data::I32(data) }
    }

    pub fn pred(dims: &[usize], data: Vec<bool>) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor { dtype: DType::Pred, dims: dims.to_vec(), data: Data::Pred(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(&[], vec![v])
    }

    pub fn scalar_i64(v: i64) -> Tensor {
        Tensor::i64(&[], vec![v])
    }

    pub fn zeros(dtype: DType, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(dims, vec![0.0; n]),
            DType::I64 => Tensor::i64(dims, vec![0; n]),
            DType::I32 => Tensor::i32(dims, vec![0; n]),
            DType::Pred => Tensor::pred(dims, vec![false; n]),
        }
    }

    pub fn filled_f32(dims: &[usize], v: f32) -> Tensor {
        Tensor::f32(dims, vec![v; dims.iter().product()])
    }

    pub fn from_literal(lit: &Literal, dims: &[usize]) -> Tensor {
        match lit {
            Literal::F32(v) => Tensor::f32(dims, v.clone()),
            Literal::I64(v) => Tensor::i64(dims, v.clone()),
            Literal::I32(v) => Tensor::i32(dims, v.clone()),
            Literal::Pred(v) => Tensor::pred(dims, v.clone()),
        }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.elems() * self.dtype.byte_size()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.dims)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is {:?}, expected f32", self.dtype),
        }
    }

    pub fn as_i64(&self) -> Result<&[i64]> {
        match &self.data {
            Data::I64(v) => Ok(v),
            _ => bail!("tensor is {:?}, expected i64", self.dtype),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is {:?}, expected i32", self.dtype),
        }
    }

    pub fn as_pred(&self) -> Result<&[bool]> {
        match &self.data {
            Data::Pred(v) => Ok(v),
            _ => bail!("tensor is {:?}, expected pred", self.dtype),
        }
    }

    /// Scalar i64 view (rank 0 or single element), for shape calculation.
    pub fn scalar_i64_value(&self) -> Result<i64> {
        ensure!(self.elems() == 1, "expected single-element tensor");
        match &self.data {
            Data::I64(v) => Ok(v[0]),
            Data::I32(v) => Ok(v[0] as i64),
            _ => bail!("expected integer tensor"),
        }
    }

    /// Reshape without moving data (element counts must match).
    pub fn with_dims(mut self, dims: &[usize]) -> Result<Tensor> {
        ensure!(
            dims.iter().product::<usize>() == self.elems(),
            "reshape element count mismatch: {:?} -> {:?}",
            self.dims,
            dims
        );
        self.dims = dims.to_vec();
        Ok(self)
    }

    /// Maximum absolute difference against another f32 tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        ensure!(self.dims == other.dims, "shape mismatch {:?} vs {:?}", self.dims, other.dims);
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max))
    }

    /// Concatenate tensors along axis 0 (the row-major leading axis, so
    /// this is a flat buffer concatenation). All parts must agree on dtype
    /// and trailing dims. Used by cross-request batching to stack member
    /// inputs.
    pub fn concat0(parts: &[&Tensor]) -> Result<Tensor> {
        ensure!(!parts.is_empty(), "concat0 of zero tensors");
        let first = parts[0];
        ensure!(first.rank() >= 1, "concat0 needs rank >= 1");
        let trailing = &first.dims[1..];
        let mut rows = 0usize;
        for p in parts {
            ensure!(p.dtype == first.dtype, "concat0 dtype mismatch");
            ensure!(
                p.rank() == first.rank() && &p.dims[1..] == trailing,
                "concat0 trailing-dim mismatch: {:?} vs {:?}",
                p.dims,
                first.dims
            );
            rows += p.dims[0];
        }
        let mut dims = first.dims.clone();
        dims[0] = rows;
        let data = match &first.data {
            Data::F32(_) => {
                let mut out = Vec::with_capacity(rows * trailing.iter().product::<usize>());
                for p in parts {
                    out.extend_from_slice(p.as_f32()?);
                }
                Data::F32(out)
            }
            Data::I64(_) => {
                let mut out = Vec::new();
                for p in parts {
                    out.extend_from_slice(p.as_i64()?);
                }
                Data::I64(out)
            }
            Data::I32(_) => {
                let mut out = Vec::new();
                for p in parts {
                    out.extend_from_slice(p.as_i32()?);
                }
                Data::I32(out)
            }
            Data::Pred(_) => {
                let mut out = Vec::new();
                for p in parts {
                    out.extend_from_slice(p.as_pred()?);
                }
                Data::Pred(out)
            }
        };
        Ok(Tensor { dtype: first.dtype, dims, data })
    }

    /// Extract `rows` leading-axis rows starting at `start` (a contiguous
    /// range of the flat buffer). The inverse of [`Tensor::concat0`].
    pub fn slice0(&self, start: usize, rows: usize) -> Result<Tensor> {
        ensure!(self.rank() >= 1, "slice0 needs rank >= 1");
        ensure!(
            start + rows <= self.dims[0],
            "slice0 range {start}..{} out of {} rows",
            start + rows,
            self.dims[0]
        );
        let row: usize = self.dims[1..].iter().product();
        let (lo, hi) = (start * row, (start + rows) * row);
        let mut dims = self.dims.clone();
        dims[0] = rows;
        let data = match &self.data {
            Data::F32(v) => Data::F32(v[lo..hi].to_vec()),
            Data::I64(v) => Data::I64(v[lo..hi].to_vec()),
            Data::I32(v) => Data::I32(v[lo..hi].to_vec()),
            Data::Pred(v) => Data::Pred(v[lo..hi].to_vec()),
        };
        Ok(Tensor { dtype: self.dtype, dims, data })
    }

    /// Relative-tolerance comparison used across the test suite.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> Result<bool> {
        ensure!(self.dims == other.dims, "shape mismatch {:?} vs {:?}", self.dims, other.dims);
        match (&self.data, &other.data) {
            (Data::F32(a), Data::F32(b)) => Ok(a
                .iter()
                .zip(b)
                .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs().max(x.abs()))),
            (Data::I64(a), Data::I64(b)) => Ok(a == b),
            (Data::I32(a), Data::I32(b)) => Ok(a == b),
            (Data::Pred(a), Data::Pred(b)) => Ok(a == b),
            _ => bail!("dtype mismatch in allclose"),
        }
    }
}

/// Row-major strides for a dim vector.
pub fn strides_of(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Convert a linear index into multi-dim coordinates.
pub fn unravel(mut idx: usize, dims: &[usize]) -> Vec<usize> {
    let mut coord = vec![0usize; dims.len()];
    for i in (0..dims.len()).rev() {
        coord[i] = idx % dims[i];
        idx /= dims[i];
    }
    coord
}

/// Convert multi-dim coordinates into a linear index.
pub fn ravel(coord: &[usize], strides: &[usize]) -> usize {
    coord.iter().zip(strides).map(|(c, s)| c * s).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.byte_size(), 24);
        assert_eq!(t.strides(), vec![3, 1]);
        assert!(t.as_i64().is_err());
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
    }

    #[test]
    fn ravel_roundtrip() {
        let dims = [2usize, 3, 4];
        let strides = strides_of(&dims);
        for i in 0..24 {
            let c = unravel(i, &dims);
            assert_eq!(ravel(&c, &strides), i);
        }
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::f32(&[2], vec![1.0, 2.0]);
        let b = Tensor::f32(&[2], vec![1.0 + 1e-7, 2.0 - 1e-7]);
        assert!(a.allclose(&b, 1e-5, 1e-5).unwrap());
        let c = Tensor::f32(&[2], vec![1.1, 2.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5).unwrap());
    }

    #[test]
    fn scalar_access() {
        assert_eq!(Tensor::scalar_i64(7).scalar_i64_value().unwrap(), 7);
        assert!(Tensor::scalar_f32(1.0).scalar_i64_value().is_err());
    }

    #[test]
    fn concat0_and_slice0_roundtrip() {
        let a = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::f32(&[1, 3], vec![7., 8., 9.]);
        let c = Tensor::concat0(&[&a, &b]).unwrap();
        assert_eq!(c.dims, vec![3, 3]);
        assert_eq!(c.as_f32().unwrap()[6..], [7., 8., 9.]);
        assert_eq!(c.slice0(0, 2).unwrap(), a);
        assert_eq!(c.slice0(2, 1).unwrap(), b);
        // dtype and trailing-dim mismatches are rejected.
        let d = Tensor::i64(&[1], vec![1]);
        assert!(Tensor::concat0(&[&a, &d]).is_err());
        let e = Tensor::f32(&[2, 4], vec![0.0; 8]);
        assert!(Tensor::concat0(&[&a, &e]).is_err());
        assert!(c.slice0(2, 2).is_err());
    }

    #[test]
    fn with_dims_checks_count() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert!(t.clone().with_dims(&[3, 2]).is_ok());
        assert!(t.with_dims(&[4, 2]).is_err());
    }
}
