//! Symbolic-shape memory planning for the device arena (BladeDISC++).
//!
//! The generated step sequence fixes, at compile time, which values become
//! device-resident (`LibraryCall` / `LaunchFused` outputs) and when they
//! die (`Dealloc` placement). What it does *not* fix is their byte sizes —
//! those depend on the per-request symbol bindings. This pass closes the
//! gap symbolically: every planned value's size is a **monomial**
//! `coeff × Π bucket(sym)` over canonical symbols
//! ([`SymbolTable::size_monomial`](crate::shape::SymbolTable::size_monomial)),
//! and monomials can be compared *for
//! all bindings*:
//!
//! * **equal** monomials → the values are always the same size;
//! * `A` is **provably ≤** `B` under the bucket policy's lower bound
//!   (`A`'s symbols are a sub-multiset of `B`'s, and `A`'s coefficient is
//!   covered by `B`'s residual symbols at the smallest bucket) → `A`
//!   always fits where `B` fits;
//! * otherwise **incomparable** → sharing is still legal between values
//!   whose live intervals are disjoint, the slot just sizes as the `max`
//!   of its members per binding.
//!
//! [`MemoryPlan::build`] walks the steps once per program: live intervals
//! from birth step to `Dealloc`, then greedy first-fit slot assignment in
//! birth order (interval-graph coloring — greedy-by-left-endpoint uses the
//! minimum possible slot count). [`MemoryPlan::instantiate`] evaluates the
//! plan against one binding at plan-record time, yielding a [`PlanMemory`]
//! with concrete slot offsets/sizes; replay then acquires **one** planned
//! extent from the [`DeviceArena`](crate::runtime::buffers::DeviceArena)
//! instead of a block per intermediate, so the arena's footprint is the
//! planned peak rather than one parked free-list entry per distinct
//! buffer size.
//!
//! Fallback: a binding whose observed buffers don't match the plan (an
//! unplanned value, or an observed size above its symbolic bound) gets
//! `None` from `instantiate`, and the launch plan keeps the pre-planner
//! behavior — per-buffer acquisition plus an observed-peak reservation.

use crate::codegen::BucketPolicy;
use crate::dhlo::ValueId;
use crate::program::{Program, Step};
use crate::shape::{ShapeExpr, SymId};
use std::collections::HashMap;

/// A symbolic buffer size: `coeff` bytes times the product of the bucketed
/// extents of `syms` (a sorted multiset of canonical symbols — a symbol
/// listed twice contributes its extent squared).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SizeMono {
    pub coeff: u64,
    pub syms: Vec<SymId>,
}

impl SizeMono {
    /// Concrete bytes under `bindings`, bucketing every symbolic extent.
    /// `None` if any symbol is unbound.
    pub fn eval(&self, bindings: &HashMap<SymId, i64>, policy: BucketPolicy) -> Option<u64> {
        let mut n = self.coeff;
        for s in &self.syms {
            let v = *bindings.get(s)?;
            if v < 0 {
                return None;
            }
            n = n.saturating_mul(policy.bucket(v as usize) as u64);
        }
        Some(n)
    }

    /// `self`'s bytes when every symbolic extent sits at the bucket lower
    /// bound `lo` — the deterministic score the greedy assignment uses to
    /// pick the least-growth slot among incomparable candidates.
    fn eval_at_lo(&self, lo: u64) -> u64 {
        self.syms.iter().fold(self.coeff, |n, _| n.saturating_mul(lo))
    }

    /// Provably `self ≤ other` for *every* binding, given that each
    /// bucketed extent is at least `lo`: cancel common symbols
    /// (multiset-wise); `self` must have none left over, and its
    /// coefficient must be covered by `other`'s residual symbols at `lo`.
    fn le_under(&self, other: &SizeMono, lo: u64) -> bool {
        let mut residual = other.syms.clone();
        for s in &self.syms {
            match residual.iter().position(|r| r == s) {
                Some(i) => {
                    residual.remove(i);
                }
                None => return false,
            }
        }
        let floor = residual.iter().fold(other.coeff, |n, _| n.saturating_mul(lo));
        self.coeff <= floor
    }

    /// The symbolic form as a [`ShapeExpr`] (constant times symbol dims) —
    /// the slot-size expressions the plan reports are maxes over these.
    pub fn expr(&self) -> ShapeExpr {
        let mut e = ShapeExpr::Const(self.coeff as i64);
        for &s in &self.syms {
            e = ShapeExpr::mul(e, ShapeExpr::Dim(crate::shape::Dim::Sym(s)));
        }
        e
    }
}

/// Live interval of a planned value, in step indices: born producing step
/// `birth`, last live during step `death` (its `Dealloc` index, or one
/// past the final step when never deallocated). Two values may share a
/// slot only if their `[birth, death)` intervals are disjoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveRange {
    pub birth: usize,
    pub death: usize,
}

impl LiveRange {
    fn overlaps(&self, other: &LiveRange) -> bool {
        self.birth < other.death && other.birth < self.death
    }
}

/// One planned arena slot: its symbolic size is the max over `monos` (an
/// antichain — monos provably ≤ another member are pruned), and `members`
/// lists every value assigned to it.
#[derive(Debug, Clone)]
pub struct SlotSpec {
    pub monos: Vec<SizeMono>,
    pub members: Vec<ValueId>,
}

impl SlotSpec {
    /// The slot's symbolic size: a `Max` over its antichain of monomials.
    pub fn size_expr(&self) -> ShapeExpr {
        let mut it = self.monos.iter();
        let first = it.next().map(SizeMono::expr).unwrap_or(ShapeExpr::Const(0));
        it.fold(first, |acc, m| ShapeExpr::max(acc, m.expr()))
    }
}

/// The compile-time symbolic memory plan for one program: slot assignment
/// for every plannable device-resident value, built once per program and
/// shared by solo and batch plans (both index the same `ValueId` space).
#[derive(Debug)]
pub struct MemoryPlan {
    pub slots: Vec<SlotSpec>,
    pub slot_of: HashMap<ValueId, usize>,
    pub ranges: HashMap<ValueId, LiveRange>,
    monos: HashMap<ValueId, SizeMono>,
    /// Bucket lower bound every ordering proof assumed.
    lo: u64,
}

/// A [`MemoryPlan`] instantiated for one binding: concrete slot offsets
/// and sizes inside a single planned extent. Carried by the launch plan;
/// replay acquires `planned_peak_bytes` once and indexes slots.
#[derive(Debug, Clone)]
pub struct PlanMemory {
    /// Byte offset of each slot inside the planned extent.
    pub slot_offsets: Vec<u64>,
    /// Concrete byte size of each slot under this binding.
    pub slot_bytes: Vec<u64>,
    /// Total planned extent — what replay acquires from the arena.
    pub planned_peak_bytes: u64,
    /// Bytes the plan reuses vs. giving every value its own block:
    /// `Σ member bytes − planned peak`.
    pub reuse_bytes: u64,
}

impl PlanMemory {
    /// Offset of a value's slot inside the planned extent.
    pub fn offset_of(&self, plan: &MemoryPlan, v: ValueId) -> Option<u64> {
        plan.slot_of.get(&v).map(|&s| self.slot_offsets[s])
    }
}

impl MemoryPlan {
    /// Walk `prog`'s step sequence and assign every plannable
    /// device-resident value (library-call and fused-launch outputs before
    /// any data-dependent suffix) to a slot.
    pub fn build(prog: &Program, policy: BucketPolicy) -> MemoryPlan {
        let m = &prog.module;
        let lo = policy.bucket(1) as u64;

        // Pass 1: births (device-producing steps) and deaths (Dealloc),
        // cut at the data-dependent suffix exactly like the plan recorder
        // (replay hands off to the interpreter there; the suffix manages
        // its own buffers).
        let mut order: Vec<ValueId> = Vec::new();
        let mut births: HashMap<ValueId, usize> = HashMap::new();
        let mut monos: HashMap<ValueId, SizeMono> = HashMap::new();
        let mut cut = prog.steps.len();
        for (si, step) in prog.steps.iter().enumerate() {
            let produced = match step {
                Step::LibraryCall { value } => Some(*value),
                Step::LaunchFused { idx } => Some(prog.fused[*idx].root),
                Step::LaunchOp { value } => {
                    if matches!(m.instrs[*value].op, crate::dhlo::Op::Unique) {
                        cut = si;
                        break;
                    }
                    None
                }
                _ => None,
            };
            if let Some(v) = produced {
                let ty = m.ty(v);
                let (elems, syms) = m.syms.size_monomial(&ty.dims);
                births.insert(v, si);
                monos.insert(
                    v,
                    SizeMono { coeff: elems.saturating_mul(ty.dtype.byte_size() as u64), syms },
                );
                order.push(v);
            }
        }
        let mut ranges: HashMap<ValueId, LiveRange> = HashMap::new();
        for (&v, &birth) in &births {
            ranges.insert(v, LiveRange { birth, death: cut });
        }
        for (si, step) in prog.steps.iter().enumerate().take(cut) {
            if let Step::Dealloc { value } = step {
                if let Some(r) = ranges.get_mut(value) {
                    r.death = r.death.min(si.max(r.birth + 1));
                }
            }
        }

        // Pass 2: greedy first-fit in birth order. Candidate slots are
        // those whose every member's interval is disjoint from the new
        // value's; prefer (1) a slot already holding an equal monomial,
        // then (2) one whose max provably covers the new value (nesting:
        // zero symbolic growth), then (3) the incomparable candidate whose
        // `max` grows least at the bucket lower bound; else a new slot.
        let mut slots: Vec<SlotSpec> = Vec::new();
        let mut slot_of: HashMap<ValueId, usize> = HashMap::new();
        for &v in &order {
            let range = ranges[&v];
            let mono = monos[&v].clone();
            let free: Vec<usize> = (0..slots.len())
                .filter(|&i| {
                    slots[i].members.iter().all(|mv| !ranges[mv].overlaps(&range))
                })
                .collect();
            let equal = free
                .iter()
                .copied()
                .find(|&i| slots[i].monos.iter().any(|sm| *sm == mono));
            let nest = equal.or_else(|| {
                free.iter()
                    .copied()
                    .find(|&i| slots[i].monos.iter().any(|sm| mono.le_under(sm, lo)))
            });
            let chosen = nest.or_else(|| {
                // Least added bytes at the lower bound, slot index as the
                // deterministic tiebreak.
                free.iter()
                    .copied()
                    .map(|i| {
                        let cur: u64 =
                            slots[i].monos.iter().map(|sm| sm.eval_at_lo(lo)).max().unwrap_or(0);
                        let grown = cur.max(mono.eval_at_lo(lo));
                        (grown - cur, i)
                    })
                    .min()
                    .map(|(_, i)| i)
            });
            match chosen {
                Some(i) => {
                    let keep = !slots[i].monos.iter().any(|sm| mono.le_under(sm, lo));
                    if keep {
                        // The new mono joins the antichain; drop members it
                        // now dominates.
                        slots[i].monos.retain(|sm| !sm.le_under(&mono, lo));
                        slots[i].monos.push(mono);
                    }
                    slots[i].members.push(v);
                    slot_of.insert(v, i);
                }
                None => {
                    slot_of.insert(v, slots.len());
                    slots.push(SlotSpec { monos: vec![mono], members: vec![v] });
                }
            }
        }
        MemoryPlan { slots, slot_of, ranges, monos, lo }
    }

    /// Number of planned values.
    pub fn planned_values(&self) -> usize {
        self.slot_of.len()
    }

    /// Instantiate the plan for one binding at plan-record time.
    ///
    /// `observed` maps each device-producing value the recorder saw to its
    /// concrete bucket bytes; every slot sizes as the max over its
    /// observed members. Symbolic evaluation cross-checks the model:
    /// returns `None` — observed-peak fallback — when the recorder
    /// produced a value the plan never assigned a slot, or when a value's
    /// observed bytes exceed its symbolic size under `bindings` (the
    /// ordering proofs would be unsound for this program).
    pub fn instantiate(
        &self,
        bindings: &HashMap<SymId, i64>,
        policy: BucketPolicy,
        observed: &HashMap<ValueId, u64>,
    ) -> Option<PlanMemory> {
        let mut slot_bytes = vec![0u64; self.slots.len()];
        let mut total_member_bytes = 0u64;
        for (&v, &bytes) in observed {
            let &slot = self.slot_of.get(&v)?;
            if let Some(sym) = self.monos[&v].eval(bindings, policy) {
                if bytes > sym {
                    return None;
                }
            }
            slot_bytes[slot] = slot_bytes[slot].max(bytes);
            total_member_bytes += bytes;
        }
        let mut slot_offsets = vec![0u64; self.slots.len()];
        let mut off = 0u64;
        for (i, &b) in slot_bytes.iter().enumerate() {
            slot_offsets[i] = off;
            off += b;
        }
        Some(PlanMemory {
            slot_offsets,
            slot_bytes,
            planned_peak_bytes: off,
            reuse_bytes: total_member_bytes.saturating_sub(off),
        })
    }

    /// The bucket lower bound the ordering proofs assumed (diagnostics).
    pub fn lower_bound(&self) -> u64 {
        self.lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mono(coeff: u64, syms: &[u32]) -> SizeMono {
        SizeMono { coeff, syms: syms.iter().map(|&s| SymId(s)).collect() }
    }

    #[test]
    fn ordering_under_bucket_lower_bound() {
        // 64·s ≤ 4·s·s at lo=16: cancel one s, 64 ≤ 4·16.
        assert!(mono(64, &[0]).le_under(&mono(4, &[0, 0]), 16));
        // 256·s ≤ 4·s·s needs 256 ≤ 64: not provable at lo=16.
        assert!(!mono(256, &[0]).le_under(&mono(4, &[0, 0]), 16));
        // Equal monomials are mutually ≤.
        assert!(mono(8, &[1]).le_under(&mono(8, &[1]), 1));
        // Sub-multiset is required: s² ≰ s·t even with a huge coefficient.
        assert!(!mono(1, &[0, 0]).le_under(&mono(1_000_000, &[0, 1]), 16));
        // Pure constants compare directly.
        assert!(mono(100, &[]).le_under(&mono(100, &[]), 1));
        assert!(!mono(101, &[]).le_under(&mono(100, &[]), 1));
    }

    #[test]
    fn eval_buckets_extents() {
        let m = mono(4, &[0, 0]);
        let mut b = HashMap::new();
        b.insert(SymId(0), 17i64);
        // MultipleOf(16) buckets 17 → 32.
        assert_eq!(m.eval(&b, BucketPolicy::MultipleOf(16)), Some(4 * 32 * 32));
        assert_eq!(mono(7, &[]).eval(&b, BucketPolicy::Exact), Some(7));
        assert_eq!(mono(1, &[3]).eval(&b, BucketPolicy::Exact), None, "unbound symbol");
    }

    // A tiny hand-built plan via the public pieces: exercise instantiate's
    // sizing, fallback, and reuse accounting without a full Program.
    fn two_slot_plan() -> MemoryPlan {
        // Values 0 and 2 share slot 0 (disjoint intervals, incomparable
        // monomials → max slot); value 1 overlaps both → slot 1.
        let m0 = mono(4, &[0]);
        let m1 = mono(8, &[0]);
        let m2 = mono(4, &[0, 0]);
        let mut slot_of = HashMap::new();
        slot_of.insert(0usize, 0usize);
        slot_of.insert(2, 0);
        slot_of.insert(1, 1);
        let mut ranges = HashMap::new();
        ranges.insert(0usize, LiveRange { birth: 0, death: 2 });
        ranges.insert(1, LiveRange { birth: 1, death: 4 });
        ranges.insert(2, LiveRange { birth: 2, death: 4 });
        let mut monos = HashMap::new();
        monos.insert(0usize, m0.clone());
        monos.insert(1, m1.clone());
        monos.insert(2, m2.clone());
        MemoryPlan {
            slots: vec![
                SlotSpec { monos: vec![m0, m2], members: vec![0, 2] },
                SlotSpec { monos: vec![m1], members: vec![1] },
            ],
            slot_of,
            ranges,
            monos,
            lo: 16,
        }
    }

    #[test]
    fn instantiate_sizes_slots_as_member_max() {
        let plan = two_slot_plan();
        let mut bindings = HashMap::new();
        bindings.insert(SymId(0), 16i64);
        let mut observed = HashMap::new();
        observed.insert(0usize, 4 * 16u64);
        observed.insert(1, 8 * 16);
        observed.insert(2, 4 * 16 * 16);
        let pm = plan.instantiate(&bindings, BucketPolicy::MultipleOf(16), &observed).unwrap();
        assert_eq!(pm.slot_bytes, vec![4 * 16 * 16, 8 * 16]);
        assert_eq!(pm.planned_peak_bytes, 4 * 16 * 16 + 8 * 16);
        // Reuse: value 0's 64 bytes ride slot 0 for free.
        assert_eq!(pm.reuse_bytes, 4 * 16);
        // Offsets partition the extent.
        assert_eq!(pm.slot_offsets, vec![0, 4 * 16 * 16]);
        for (o, b) in pm.slot_offsets.iter().zip(&pm.slot_bytes) {
            assert!(o + b <= pm.planned_peak_bytes);
        }
    }

    #[test]
    fn instantiate_falls_back_on_unplanned_or_oversized_values() {
        let plan = two_slot_plan();
        let bindings = HashMap::new();
        let mut observed = HashMap::new();
        observed.insert(7usize, 128u64); // never planned
        assert!(plan.instantiate(&bindings, BucketPolicy::MultipleOf(16), &observed).is_none());
        let mut bindings = HashMap::new();
        bindings.insert(SymId(0), 16i64);
        let mut observed = HashMap::new();
        observed.insert(0usize, 10_000u64); // above 4·16 symbolic bound
        assert!(plan.instantiate(&bindings, BucketPolicy::MultipleOf(16), &observed).is_none());
    }

    /// Seeded property test: random binding vectors against the plan —
    /// slots never alias values with overlapping live intervals, and every
    /// planned offset+size stays inside the planned peak. Prints the
    /// failing seed for reproduction.
    #[test]
    fn property_overlapping_intervals_never_alias() {
        for seed in 0..64u64 {
            let mut rng = crate::util::prng::Prng::new(seed ^ 0x9E37);
            let plan = two_slot_plan();
            let mut bindings = HashMap::new();
            let s = (16 * rng.range(1, 8)) as i64;
            bindings.insert(SymId(0), s);
            let mut observed = HashMap::new();
            for (&v, m) in &plan.monos {
                // Observed bytes at or under the symbolic size (recorders
                // report bucket bytes, which eval reproduces exactly).
                let sym = m.eval(&bindings, BucketPolicy::MultipleOf(16)).unwrap();
                let bytes = if rng.below(2) == 0 { sym } else { sym / 2 };
                observed.insert(v, bytes);
            }
            let pm = plan
                .instantiate(&bindings, BucketPolicy::MultipleOf(16), &observed)
                .unwrap_or_else(|| panic!("instantiate failed, seed={seed}"));
            // Every member fits in its slot, inside the peak.
            for (&v, &bytes) in &observed {
                let slot = plan.slot_of[&v];
                assert!(
                    bytes <= pm.slot_bytes[slot]
                        && pm.slot_offsets[slot] + pm.slot_bytes[slot] <= pm.planned_peak_bytes,
                    "member exceeds slot or peak, seed={seed}"
                );
            }
            // Overlapping live intervals ⇒ different slots (never alias).
            let vals: Vec<_> = plan.slot_of.keys().copied().collect();
            for &a in &vals {
                for &b in &vals {
                    if a != b
                        && plan.ranges[&a].overlaps(&plan.ranges[&b])
                        && plan.slot_of[&a] == plan.slot_of[&b]
                    {
                        panic!("live values alias a slot, seed={seed}");
                    }
                }
            }
        }
    }

    #[test]
    fn slot_size_expr_is_max_over_antichain() {
        let plan = two_slot_plan();
        let e = plan.slots[0].size_expr();
        let s = format!("{e}");
        assert!(s.contains("max"), "{s}");
    }
}
