//! Loader for the AOT artifacts produced by `python/compile/aot.py`.
//!
//! `make artifacts` runs Python once; afterwards the Rust binary is
//! self-contained: this module reads `artifacts/manifest.json`, compiles
//! each HLO-text module on the PJRT client, registers the pre-generated
//! GEMM entries with the kernel library (§4.5), and exposes the bucketed
//! transformer-block variants behind the host-side *selection logic* of
//! §4.3 (pick the smallest bucket ≥ the request's length, pass the actual
//! extent as the `n` scalar, crop the output box).

use crate::dhlo::DType;
use crate::library::{GemmKey, GemmLibrary};
use crate::runtime::executor::{crop_box, pad_box};
use crate::runtime::pjrt::{Device, Executable};
use crate::runtime::tensor::Tensor;
use crate::util::json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One bucket variant of the AOT transformer block.
pub struct AotVariant {
    pub bucket: usize,
    pub exe: Executable,
}

/// The AOT-compiled encoder block family + its baked weights.
pub struct AotTransformer {
    pub hidden: usize,
    /// Ascending by bucket.
    pub variants: Vec<AotVariant>,
    /// Weights in the lowered parameter order (after `x`, `n`).
    pub weights: Vec<Tensor>,
    /// Selection + execution statistics.
    pub runs: u64,
    pub pad_copies: u64,
}

impl AotTransformer {
    /// Load the manifest, compile every model variant, parse the weights.
    pub fn load(dir: &Path, device: &Device) -> Result<AotTransformer> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
            })?;
        let manifest = json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let hidden = manifest.get("hidden").as_usize().context("manifest: hidden")?;

        let mut variants = Vec::new();
        for entry in manifest.get("models").as_arr().context("manifest: models")? {
            let path = entry.get("path").as_str().context("model path")?;
            let bucket = entry.get("bucket").as_usize().context("model bucket")?;
            let exe = device
                .compile_hlo_file(&dir.join(path))
                .with_context(|| format!("compiling {path}"))?;
            variants.push(AotVariant { bucket, exe });
        }
        variants.sort_by_key(|v| v.bucket);
        if variants.is_empty() {
            bail!("no model variants in manifest");
        }

        let weights_text = std::fs::read_to_string(dir.join("weights.json"))?;
        let wdoc = json::parse(&weights_text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let names = [
            "wq", "wk", "wv", "wo", "ln1_g", "ln1_b", "w1", "b1", "w2", "b2", "ln2_g", "ln2_b",
        ];
        let mut weights = Vec::with_capacity(names.len());
        for name in names {
            let entry = wdoc.get(name);
            let dims: Vec<usize> = entry
                .get("dims")
                .as_arr()
                .context("weight dims")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let data: Vec<f32> = entry
                .get("data")
                .as_arr()
                .context("weight data")?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect();
            weights.push(Tensor::f32(&dims, data));
        }

        Ok(AotTransformer { hidden, variants, weights, runs: 0, pad_copies: 0 })
    }

    /// The §4.3 selection logic: smallest bucket that fits.
    pub fn select(&self, n: usize) -> Result<&AotVariant> {
        self.variants
            .iter()
            .find(|v| v.bucket >= n)
            .with_context(|| format!("sequence length {n} exceeds largest bucket"))
    }

    /// Run one request `x: [n, hidden]` through the right variant.
    pub fn run(&mut self, x: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(x.rank() == 2 && x.dims[1] == self.hidden, "bad input shape");
        let n = x.dims[0];
        let variant = self
            .variants
            .iter()
            .find(|v| v.bucket >= n)
            .with_context(|| format!("sequence length {n} exceeds largest bucket"))?;
        let padded = if n == variant.bucket {
            x.clone()
        } else {
            self.pad_copies += 1;
            pad_box(x, &[variant.bucket, self.hidden], None)?
        };
        let n_scalar = Tensor::i32(&[], vec![n as i32]);
        let mut args: Vec<&Tensor> = vec![&padded, &n_scalar];
        args.extend(self.weights.iter());
        let outs = variant
            .exe
            .run_tuple(&args, &[(vec![variant.bucket, self.hidden], DType::F32)])?;
        self.runs += 1;
        let out = outs.into_iter().next().unwrap();
        if n == variant.bucket {
            Ok(out)
        } else {
            crop_box(&out, &[n, self.hidden])
        }
    }
}

/// Register the pre-generated GEMM artifacts as §4.5 library entries.
pub fn register_gemms(dir: &Path, device: &Device, lib: &mut GemmLibrary) -> Result<usize> {
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let manifest = json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut count = 0;
    for entry in manifest.get("gemms").as_arr().context("manifest: gemms")? {
        let path = entry.get("path").as_str().context("gemm path")?;
        let key = GemmKey {
            batch: 0,
            m: entry.get("m").as_usize().context("m")?,
            k: entry.get("k").as_usize().context("k")?,
            n: entry.get("n").as_usize().context("n")?,
        };
        let exe = device.compile_hlo_file(&dir.join(path))?;
        lib.register_pregen(key, exe);
        count += 1;
    }
    Ok(count)
}

/// Default artifacts directory: `$DISC_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> std::path::PathBuf {
    std::env::var_os("DISC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_and_runs_aot_transformer() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let device = Device::cpu().unwrap();
        let mut model = AotTransformer::load(&default_dir(), &device).unwrap();
        assert!(model.variants.len() >= 2);
        let mut rng = crate::util::prng::Prng::new(77);
        for n in [7usize, 32, 50, 100] {
            let x = Tensor::f32(&[n, model.hidden], rng.fill_f32(n * model.hidden, 1.0));
            let out = model.run(&x).unwrap();
            assert_eq!(out.dims, vec![n, model.hidden]);
            let v = out.as_f32().unwrap();
            assert!(v.iter().all(|x| x.is_finite()));
            // LayerNormed outputs: every row ~zero mean.
            let h = model.hidden;
            let row0: f32 = v[..h].iter().sum::<f32>() / h as f32;
            assert!(row0.abs() < 0.15, "row mean {row0}");
        }
    }

    #[test]
    fn masking_isolates_requests_from_padding() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let device = Device::cpu().unwrap();
        let mut model = AotTransformer::load(&default_dir(), &device).unwrap();
        // Same logical request at two lengths landing in the same bucket:
        // the first `n` rows must agree exactly with a direct computation
        // at any padding amount.
        let mut rng = crate::util::prng::Prng::new(78);
        let n = 20usize;
        let x = Tensor::f32(&[n, model.hidden], rng.fill_f32(n * model.hidden, 1.0));
        let out1 = model.run(&x).unwrap();
        let out2 = model.run(&x).unwrap();
        assert!(out1.allclose(&out2, 0.0, 0.0).unwrap(), "deterministic");
    }

    #[test]
    fn gemm_registration() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let device = std::sync::Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(device.clone());
        let n = register_gemms(&default_dir(), &device, &mut lib).unwrap();
        assert!(n >= 5);
        assert!(lib.has_pregen(&GemmKey { batch: 0, m: 64, k: 64, n: 64 }));
        // A pre-generated entry serves the call (no on-demand build).
        let a = Tensor::f32(&[64, 64], vec![0.01; 4096]);
        let b = Tensor::f32(&[64, 64], vec![0.01; 4096]);
        lib.matmul(&a, &b).unwrap();
        assert_eq!(lib.stats.pregen_hits, 1);
        assert_eq!(lib.stats.entries_built, 0);
    }
}
