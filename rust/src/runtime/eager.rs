//! Framework-eager baseline (the TF/PyTorch comparator of Fig. 3).
//!
//! Frameworks execute a graph one op at a time: every memory-intensive op
//! is a separate pre-built kernel launch (off-chip round trip per op), and
//! compute-intensive ops call the vendor library. No fusion, no compile
//! step — which is exactly why the memory-intensive portion dominates the
//! paper's baselines.

use crate::dhlo::{Module, Op};
use crate::library::GemmLibrary;
use crate::runtime::executor::ExecOutput;
use crate::runtime::metrics::RunMetrics;
use crate::runtime::reference::eval_op;
use crate::runtime::shape_env::SymEnv;
use crate::runtime::tensor::Tensor;
use anyhow::{Context, Result};
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

/// Eager evaluator with vendor-library GEMMs.
pub struct Eager {
    pub library: GemmLibrary,
}

impl Eager {
    pub fn new(device: Arc<crate::runtime::pjrt::Device>) -> Self {
        Eager { library: GemmLibrary::new(device) }
    }

    pub fn run(&mut self, m: &Module, inputs: &[Tensor]) -> Result<ExecOutput> {
        let t_start = Instant::now();
        let mut metrics = RunMetrics::default();
        let mut env = SymEnv::new();
        env.bind_params(m, inputs)?;
        let flops0 = self.library.stats.flops;
        let mut vals: Vec<Option<Rc<Tensor>>> = vec![None; m.instrs.len()];

        for (id, ins) in m.instrs.iter().enumerate() {
            let t = match &ins.op {
                Op::Param { index } => Rc::new(inputs[*index].clone()),
                Op::Const { lit, dims } => Rc::new(Tensor::from_literal(lit, dims)),
                Op::Dot => {
                    let a = vals[ins.operands[0]].as_deref().unwrap();
                    let b = vals[ins.operands[1]].as_deref().unwrap();
                    metrics.lib_bytes += (a.byte_size() + b.byte_size()) as u64;
                    let build0 = self.library.stats.build_time;
                    let exec0 = self.library.stats.exec_time;
                    let out = self.library.matmul(a, b)?;
                    metrics.lib_time += self.library.stats.exec_time - exec0;
                    metrics.compile_time += self.library.stats.build_time - build0;
                    metrics.lib_calls += 1;
                    metrics.lib_bytes += out.byte_size() as u64;
                    Rc::new(out)
                }
                Op::Reshape | Op::DReshape => {
                    // Frameworks treat reshape as a view.
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &vals[..])?;
                    metrics.bitcasts += 1;
                    let src = vals[ins.operands[0]].as_deref().unwrap().clone();
                    Rc::new(src.with_dims(&out_dims)?)
                }
                op => {
                    let out_dims = if matches!(op, Op::Unique) {
                        vec![]
                    } else {
                        env.resolve_dims(m, &ins.ty.dims, &vals[..])
                            .with_context(|| format!("eager shapes of %{id}"))?
                    };
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| vals[o].as_deref().unwrap()).collect();
                    for o in &operands {
                        metrics.mem_bytes += o.byte_size() as u64;
                    }
                    let tk = Instant::now();
                    let out = eval_op(op, &operands, &out_dims, ins.ty.dtype)?;
                    metrics.kernel_time += tk.elapsed();
                    metrics.mem_kernels += 1;
                    metrics.mem_bytes += out.byte_size() as u64;
                    if matches!(op, Op::Unique) {
                        env.set_datadep(m, id, out.dims[0] as i64);
                    }
                    Rc::new(out)
                }
            };
            vals[id] = Some(t);
        }

        let outputs: Vec<Tensor> =
            m.outputs.iter().map(|&o| vals[o].as_deref().unwrap().clone()).collect();
        metrics.flops = self.library.stats.flops - flops0;
        metrics.total_time = t_start.elapsed();
        Ok(ExecOutput { outputs, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::runtime::pjrt::Device;
    use crate::runtime::reference::eval_module;
    use crate::shape::Dim;

    #[test]
    fn eager_matches_reference_and_counts_per_op() {
        let mut b = Builder::new("eager");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let sm = b.softmax_last(x).unwrap();
        let t = b.unary(UnKind::Tanh, sm);
        let m = b.finish(vec![t]);
        let dev = Arc::new(Device::cpu().unwrap());
        let mut eager = Eager::new(dev);
        let input = Tensor::f32(&[3, 4], (0..12).map(|i| i as f32 * 0.1).collect());
        let got = eager.run(&m, &[input.clone()]).unwrap();
        let want = eval_module(&m, &[input]).unwrap();
        assert!(got.outputs[0].allclose(&want.outputs[0], 1e-6, 1e-6).unwrap());
        // softmax expands to 7 memory ops + tanh = 8 launches.
        assert_eq!(got.metrics.mem_kernels, 8);
    }

    #[test]
    fn eager_uses_library_for_dot() {
        let mut b = Builder::new("eagerdot");
        let x = b.param(DType::F32, vec![Dim::Fixed(2), Dim::Fixed(2)]);
        let d = b.dot(x, x).unwrap();
        let m = b.finish(vec![d]);
        let dev = Arc::new(Device::cpu().unwrap());
        let mut eager = Eager::new(dev);
        let input = Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let got = eager.run(&m, &[input]).unwrap();
        assert_eq!(got.metrics.lib_calls, 1);
        assert_eq!(got.metrics.mem_kernels, 0);
        assert_eq!(got.outputs[0].as_f32().unwrap(), &[7., 10., 15., 22.]);
    }
}
