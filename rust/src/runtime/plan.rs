//! Launch plans: the fully resolved runtime flow for one symbol binding.
//!
//! The generated program (`crate::program`) already removed graph
//! interpretation from the hot path, but each request still re-resolved
//! every symbolic dim, re-hashed kernel-cache keys, and re-decided pad/crop
//! marshalling. A [`LaunchPlan`] records the outcome of all of that work
//! the first time a binding vector (the concrete extents of the module's
//! dynamic dims) is seen: concrete dims per step, the compiled kernel and
//! extent-scalar arguments per fused launch, the GEMM library entry — and
//! cached device-weight slot ([`PlanWeight`]) — per dot. Repeat requests
//! with the same bindings *replay* the plan — no
//! `resolve_dims`, no signature hashing, no per-launch branching — and run
//! device-resident (see `executor::Executor::replay`).
//!
//! Two safety mechanisms keep replays exact:
//!
//! * **Guards** — shapes that were resolved from host shape-tensor
//!   *contents* (`ShapeExpr::Elem` reads, e.g. `DSlice` bounds) are not
//!   captured by the binding vector. Every such read is logged during
//!   recording; replays re-check the observed values (against the request's
//!   inputs for parameter tensors, or right after the producing host op
//!   runs) and fall back to interpretation on any mismatch.
//! * **Data-dependent suffix** — an `Op::Unique` produces an extent no
//!   plan can predict, so recording stops there: the plan covers the step
//!   prefix and replays hand off to the interpreter from `suffix_start`.
//!
//! **Batched dispatches** plan the same way at group granularity: a
//! [`BatchPlan`], keyed by [`BatchPlanKey`] (residual bindings + the
//! *sorted* member extents, so repeat same-shape groups hit regardless of
//! arrival order), records the whole stacked walk — one widened
//! [`PlannedStep`] per Stacked/Shared step, and a per-extent sub-record
//! per PerRequest step (the residual agrees across members, so a member's
//! leading extent determines every dim it resolves). Batch-eligible
//! programs contain no `Unique` and no content-reading shape math (the
//! batchability analysis rejects both), so batch plans always cover the
//! full flow; the guard machinery is reused unchanged and is empty in
//! practice.

use crate::codegen::cache::CompiledKernel;
use crate::dhlo::{Module, Op, ValueId};
use crate::library::GemmKey;
use crate::program::Program;
use crate::runtime::pjrt::DeviceTensor;
use crate::runtime::shape_env::SymEnv;
use crate::runtime::tensor::Tensor;
use crate::shape::SymId;
use std::collections::HashMap;
use std::sync::Arc;

/// Cache key: which program, under which concrete extents of its dynamic
/// dims (canonical symbols, sorted for determinism), recorded under which
/// bucket-policy epoch. The epoch makes plans from before a boundary swap
/// unreachable — their kernels used the old bucket family — so they retire
/// through the executor's FIFO instead of poisoning replays.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub program: u64,
    pub bindings: Vec<(SymId, i64)>,
    pub epoch: u64,
}

/// The binding vector of a freshly bound environment (call right after
/// `SymEnv::bind_params`, before any derived symbol is resolved).
pub fn binding_vector(env: &SymEnv) -> Vec<(SymId, i64)> {
    let mut v: Vec<(SymId, i64)> = env.resolved().iter().map(|(&s, &x)| (s, x)).collect();
    v.sort_unstable_by_key(|&(s, _)| s);
    v
}

/// A recorded host-shape-tensor read: element `index` of the tensor at
/// `value` (or of entry parameter `param`) evaluated to `expect`.
#[derive(Debug, Clone)]
pub struct ElemGuard {
    pub index: usize,
    pub expect: i64,
}

/// A cacheable GEMM weight reference recorded in a plan: the RHS operand's
/// value slot plus whether replays must re-validate its contents (Param
/// weights — same shape, possibly new data) or may trust it outright
/// (graph constants). Replays resolve the slot through the library's
/// persistent device-side weight cache instead of re-uploading.
#[derive(Debug, Clone, Copy)]
pub struct PlanWeight {
    pub value: ValueId,
    pub validate: bool,
}

/// One resolved step of the flow. Mirrors `program::Step`, with everything
/// the hot path would otherwise recompute baked in.
#[derive(Clone)]
pub enum PlannedStep {
    EvalHost { value: ValueId, out_dims: Vec<usize> },
    Bitcast { value: ValueId, out_dims: Vec<usize> },
    LaunchOp { value: ValueId, out_dims: Vec<usize> },
    LibraryCall { value: ValueId, key: GemmKey, weight: Option<PlanWeight> },
    LaunchFused {
        idx: usize,
        /// The compiled kernel — replays skip signature hashing and the
        /// bucket-cache lookup entirely.
        kernel: Arc<CompiledKernel>,
        /// Actual extents of the kernel's trailing s32 scalar parameters,
        /// as host tensors (host-path replay)…
        extents_host: Vec<Tensor>,
        /// …and pre-uploaded device buffers (device-resident replay).
        extents_dev: Vec<Arc<DeviceTensor>>,
        /// Actual (cropped) output dims.
        out_actual: Vec<usize>,
    },
    Dealloc { value: ValueId },
}

/// A cached, fully resolved runtime flow for one `PlanKey`.
pub struct LaunchPlan {
    pub steps: Vec<PlannedStep>,
    /// Index into `Program::steps` where replay falls back to the
    /// interpreter (`== steps len of the program` when fully covered).
    pub suffix_start: usize,
    /// Guards over entry-parameter shape tensors, checked before replay.
    pub param_guards: HashMap<usize, Vec<ElemGuard>>,
    /// Guards over host-op products, checked as the producing op replays.
    pub host_guards: HashMap<ValueId, Vec<ElemGuard>>,
    /// Peak bytes of device-resident values implied by the plan's
    /// compile-time `Dealloc` placement; the reservation fallback when no
    /// symbolic memory plan instantiates for this binding.
    pub device_peak_bytes: u64,
    /// Instantiated symbolic memory plan for this binding (slot offsets and
    /// sizes, planned peak): replay acquires one planned extent instead of
    /// per-buffer blocks. `None` → observed-peak fallback.
    pub memory: Option<crate::runtime::memplan::PlanMemory>,
    /// Arena reservation held for the plan's whole cache lifetime; dropping
    /// the plan (FIFO eviction) drops the lease and shrinks the arena's
    /// reserved capacity.
    pub reserve: Option<crate::runtime::buffers::ArenaLease>,
    /// Fused-launch elements one replay of this plan moves (bucket
    /// extents), and how many of them are bucket padding — captured from
    /// the recording run so replays keep the padding counters honest
    /// without re-deriving shapes.
    pub launch_elems: u64,
    pub padded_elems: u64,
}

/// Check a parameter-guard map against one request's inputs. `true` means
/// the recorded flow is valid for that request (shared by the solo and
/// batched plans).
fn param_guards_hold_for(guards: &HashMap<usize, Vec<ElemGuard>>, inputs: &[Tensor]) -> bool {
    guards.iter().all(|(&param, guards)| {
        let Some(t) = inputs.get(param) else { return false };
        let Ok(v) = t.as_i64() else { return false };
        guards.iter().all(|g| v.get(g.index) == Some(&g.expect))
    })
}

impl LaunchPlan {
    /// Check the parameter guards against a request's inputs. `true` means
    /// the recorded flow is valid for this request.
    pub fn param_guards_hold(&self, inputs: &[Tensor]) -> bool {
        param_guards_hold_for(&self.param_guards, inputs)
    }
}

/// Check one host value against its recorded guards.
pub fn host_guards_hold(guards: &[ElemGuard], t: &Tensor) -> bool {
    let Ok(v) = t.as_i64() else { return false };
    guards.iter().all(|g| v.get(g.index) == Some(&g.expect))
}

/// Classify a recorded shape-read log into parameter guards (checked
/// against request inputs before replay) and host-op guards (checked as
/// the producing op replays). Constants need no guard — they cannot change
/// for a given program. Shared by the solo and batched plan recorders.
fn classify_elem_log(
    m: &Module,
    elem_log: &[(usize, usize, i64)],
) -> (HashMap<usize, Vec<ElemGuard>>, HashMap<ValueId, Vec<ElemGuard>>) {
    let mut param_guards: HashMap<usize, Vec<ElemGuard>> = HashMap::new();
    let mut host_guards: HashMap<ValueId, Vec<ElemGuard>> = HashMap::new();
    let mut seen: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    for &(value, index, expect) in elem_log {
        if !seen.insert((value, index)) {
            continue;
        }
        match &m.instrs[value].op {
            // Constants never change between requests: nothing to guard.
            Op::Const { .. } => {}
            // Parameter contents vary per request even at fixed shapes:
            // check against the inputs before replaying.
            Op::Param { index: p } => {
                param_guards.entry(*p).or_default().push(ElemGuard { index, expect });
            }
            // Host-op product: re-checked right after that op replays.
            // (Reads that only happen in the interpreted suffix leave a
            // guard that is never consulted — harmless, the suffix
            // re-resolves from scratch.)
            _ => {
                host_guards.entry(value).or_default().push(ElemGuard { index, expect });
            }
        }
    }
    (param_guards, host_guards)
}

/// Plan-cache statistics (executor-lifetime).
#[derive(Debug, Clone, Default)]
pub struct PlanStats {
    pub hits: u64,
    pub misses: u64,
    pub guard_misses: u64,
    pub entries: usize,
}

/// Accumulates a [`LaunchPlan`] while the interpreter executes a request.
pub struct PlanRecorder {
    steps: Vec<PlannedStep>,
    suffix_start: Option<usize>,
    /// Elem-read log snapshotted at the suffix cut: reads that happen in
    /// the interpreted suffix must NOT become guards (the suffix
    /// re-resolves from scratch on every replay, so guarding on its reads
    /// would spuriously kill replay for e.g. `Unique` + `DSlice` programs).
    elem_log: Option<Vec<(usize, usize, i64)>>,
    /// Device-residency model: bytes each device-producing step would hold
    /// during replay, released at the recorded `Dealloc` points.
    dev_live: HashMap<ValueId, u64>,
    dev_resident: u64,
    dev_peak: u64,
    /// Every device-producing value's observed bucket bytes (never removed
    /// at `Dealloc` — the symbolic memory planner instantiates slot sizes
    /// from this map when the plan installs).
    observed: HashMap<ValueId, u64>,
}

impl PlanRecorder {
    pub fn new() -> PlanRecorder {
        PlanRecorder {
            steps: Vec::new(),
            suffix_start: None,
            elem_log: None,
            dev_live: HashMap::new(),
            dev_resident: 0,
            dev_peak: 0,
            observed: HashMap::new(),
        }
    }

    /// Observed bytes per device-producing value (read before
    /// [`finish`](Self::finish) consumes the recorder).
    pub fn observed(&self) -> &HashMap<ValueId, u64> {
        &self.observed
    }

    /// Freeze the shape-read log at the suffix cut: only reads up to here
    /// produce guards.
    pub fn stash_elem_log(&mut self, log: Vec<(usize, usize, i64)>) {
        if self.elem_log.is_none() {
            self.elem_log = Some(log);
        }
    }

    /// Still recording? (False once a data-dependent step was hit.)
    pub fn active(&self) -> bool {
        self.suffix_start.is_none()
    }

    pub fn push(&mut self, step: PlannedStep) {
        if self.active() {
            self.steps.push(step);
        }
    }

    /// A data-dependent step at program-step index `si`: the plan covers
    /// only the prefix before it.
    pub fn mark_suffix(&mut self, si: usize) {
        if self.active() {
            self.suffix_start = Some(si);
        }
    }

    /// A step whose replay output is device-resident (`bytes` at bucket
    /// extents).
    pub fn note_device_out(&mut self, value: ValueId, bytes: u64) {
        if !self.active() {
            return;
        }
        self.dev_live.insert(value, bytes);
        self.observed.insert(value, bytes);
        self.dev_resident += bytes;
        self.dev_peak = self.dev_peak.max(self.dev_resident);
    }

    pub fn note_dealloc(&mut self, value: ValueId) {
        if !self.active() {
            return;
        }
        if let Some(bytes) = self.dev_live.remove(&value) {
            self.dev_resident -= bytes;
        }
    }

    /// Finalize against the recorded environment's shape reads (the
    /// stashed prefix log wins over `elem_log` when a suffix was cut).
    /// Returns `None` when the plan would cover nothing (data-dependent
    /// first step).
    pub fn finish(
        self,
        m: &Module,
        prog: &Program,
        elem_log: &[(usize, usize, i64)],
    ) -> Option<LaunchPlan> {
        let suffix_start = self.suffix_start.unwrap_or(prog.steps.len());
        if suffix_start == 0 {
            return None;
        }
        let stashed = self.elem_log.clone();
        let elem_log: &[(usize, usize, i64)] = stashed.as_deref().unwrap_or(elem_log);
        let (param_guards, host_guards) = classify_elem_log(m, elem_log);
        Some(LaunchPlan {
            steps: self.steps,
            suffix_start,
            param_guards,
            host_guards,
            device_peak_bytes: self.dev_peak,
            memory: None,
            reserve: None,
            launch_elems: 0,
            padded_elems: 0,
        })
    }
}

impl Default for PlanRecorder {
    fn default() -> Self {
        Self::new()
    }
}

// --- batched plan record/replay -----------------------------------------

/// Cache key for a whole batch group: which program, under which residual
/// bindings (everything except the leading batch symbol — shared by every
/// member), stacking which member extents. The extents are **sorted**: the
/// stacked walk is order-independent (the widened launches see only the
/// total, and per-member sub-records key on the member's own extent), so a
/// group arriving as `[3, 2]` replays the plan a `[2, 3]` group recorded.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchPlanKey {
    pub program: u64,
    pub residual: Vec<(SymId, i64)>,
    pub extents: Vec<i64>,
    /// Bucket-policy epoch the walk was recorded under (see
    /// [`PlanKey::epoch`]).
    pub epoch: u64,
}

/// One planned step of a batched walk.
#[derive(Clone)]
pub enum BatchPlannedStep {
    /// Executed once over the joint value store: the widened record of a
    /// Stacked step, or the once-per-batch record of a Shared step.
    Joint {
        step: PlannedStep,
        /// Stacked (widened-extent launch, pad-lane traffic accounted as
        /// batch padding) vs Shared (constant-derived, no batch axis).
        stacked: bool,
    },
    /// Executed once per member. Records are keyed by the member's leading
    /// extent: residual bindings agree across the group by construction,
    /// so the extent determines every dim the member resolves (the
    /// batchability analysis rejects content-dependent shape math).
    Member { per_extent: HashMap<i64, PlannedStep> },
}

/// A cached, fully resolved stacked walk for one [`BatchPlanKey`]. Batch
/// plans always cover the whole flow — `Unique` programs are batching-
/// ineligible, so there is no data-dependent suffix to cut.
pub struct BatchPlan {
    pub steps: Vec<BatchPlannedStep>,
    /// Guards over entry-parameter shape tensors, checked against every
    /// member before replay (same machinery as [`LaunchPlan`]; empty for
    /// batch-eligible programs, which have no content-read shape math).
    pub param_guards: HashMap<usize, Vec<ElemGuard>>,
    /// Guards over joint host-op products, checked as the producing op
    /// replays.
    pub host_guards: HashMap<ValueId, Vec<ElemGuard>>,
    /// Peak bytes of device-resident joint values implied by the plan's
    /// `Dealloc` placement; the reservation fallback when no symbolic
    /// memory plan instantiates for this group shape.
    pub device_peak_bytes: u64,
    /// Instantiated symbolic memory plan for this group shape (same
    /// per-program `MemoryPlan` as solo plans, instantiated with the
    /// widened joint sizes). `None` → observed-peak fallback.
    pub memory: Option<crate::runtime::memplan::PlanMemory>,
    /// Arena reservation held for the batch plan's cache lifetime.
    pub reserve: Option<crate::runtime::buffers::ArenaLease>,
    /// Fused-launch elements one replay moves and the padded share of
    /// them (see [`LaunchPlan::launch_elems`]).
    pub launch_elems: u64,
    pub padded_elems: u64,
}

impl BatchPlan {
    /// Check the parameter guards against every member's inputs. `true`
    /// means the recorded walk is valid for this group.
    pub fn param_guards_hold(&self, requests: &[Vec<Tensor>]) -> bool {
        requests.iter().all(|inputs| param_guards_hold_for(&self.param_guards, inputs))
    }
}

/// Accumulates a [`BatchPlan`] while the batched interpret tier executes a
/// group. Joint steps land via [`push_joint`](Self::push_joint) as the
/// stacked walk records them; per-member steps collect one sub-record per
/// distinct extent and land via [`push_member`](Self::push_member). The
/// device-residency model mirrors [`PlanRecorder`]'s, over the joint lane
/// only (member sub-records replay host-side).
pub struct BatchPlanRecorder {
    steps: Vec<BatchPlannedStep>,
    dev_live: HashMap<ValueId, u64>,
    dev_resident: u64,
    dev_peak: u64,
    /// Observed joint bytes per device-producing value (kept past
    /// `Dealloc` for the symbolic memory planner, like [`PlanRecorder`]).
    observed: HashMap<ValueId, u64>,
    /// Shape reads the batched environment logged during the walk (empty
    /// for eligible programs; stashed by the executor before `finish`).
    elem_log: Vec<(usize, usize, i64)>,
}

impl BatchPlanRecorder {
    pub fn new() -> BatchPlanRecorder {
        BatchPlanRecorder {
            steps: Vec::new(),
            dev_live: HashMap::new(),
            dev_resident: 0,
            dev_peak: 0,
            observed: HashMap::new(),
            elem_log: Vec::new(),
        }
    }

    /// Observed joint bytes per device-producing value (read before
    /// [`finish`](Self::finish) consumes the recorder).
    pub fn observed(&self) -> &HashMap<ValueId, u64> {
        &self.observed
    }

    /// Hand over the batched environment's shape-read log (consumed by
    /// [`finish`](Self::finish)).
    pub fn stash_elem_log(&mut self, log: Vec<(usize, usize, i64)>) {
        self.elem_log = log;
    }

    pub fn push_joint(&mut self, step: PlannedStep, stacked: bool) {
        self.steps.push(BatchPlannedStep::Joint { step, stacked });
    }

    pub fn push_member(&mut self, per_extent: HashMap<i64, PlannedStep>) {
        self.steps.push(BatchPlannedStep::Member { per_extent });
    }

    /// A joint step whose replay output is device-resident (`bytes` at
    /// bucket extents).
    pub fn note_device_out(&mut self, value: ValueId, bytes: u64) {
        self.dev_live.insert(value, bytes);
        self.observed.insert(value, bytes);
        self.dev_resident += bytes;
        self.dev_peak = self.dev_peak.max(self.dev_resident);
    }

    pub fn note_dealloc(&mut self, value: ValueId) {
        if let Some(bytes) = self.dev_live.remove(&value) {
            self.dev_resident -= bytes;
        }
    }

    /// Finalize against the stashed shape-read log (empty for eligible
    /// programs; classified by the same rules as solo plans).
    pub fn finish(self, m: &Module) -> BatchPlan {
        let (param_guards, host_guards) = classify_elem_log(m, &self.elem_log);
        BatchPlan {
            steps: self.steps,
            param_guards,
            host_guards,
            device_peak_bytes: self.dev_peak,
            memory: None,
            reserve: None,
            launch_elems: 0,
            padded_elems: 0,
        }
    }
}

impl Default for BatchPlanRecorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_tracks_device_peak_through_deallocs() {
        let mut r = PlanRecorder::new();
        r.note_device_out(0, 100);
        r.note_device_out(1, 50);
        r.note_dealloc(0);
        r.note_device_out(2, 60);
        assert_eq!(r.dev_peak, 150, "peak before first dealloc");
        assert_eq!(r.dev_resident, 110);
    }

    #[test]
    fn suffix_marking_stops_recording() {
        let mut r = PlanRecorder::new();
        r.push(PlannedStep::Dealloc { value: 0 });
        r.mark_suffix(1);
        r.push(PlannedStep::Dealloc { value: 1 });
        r.note_device_out(5, 1000);
        assert_eq!(r.steps.len(), 1, "steps after the suffix mark are not recorded");
        assert_eq!(r.dev_peak, 0);
    }

    #[test]
    fn batch_recorder_tracks_joint_device_peak() {
        let mut r = BatchPlanRecorder::new();
        r.push_joint(PlannedStep::Dealloc { value: 9 }, false);
        r.note_device_out(0, 100);
        r.note_device_out(1, 50);
        r.note_dealloc(0);
        r.note_device_out(2, 60);
        assert_eq!(r.dev_peak, 150);
        assert_eq!(r.dev_resident, 110);
        r.push_member(HashMap::new());
        assert_eq!(r.steps.len(), 2);
    }

    #[test]
    fn batch_plan_key_distinguishes_extent_multisets() {
        let k = |extents: Vec<i64>| BatchPlanKey {
            program: 7,
            residual: vec![],
            extents,
            epoch: 0,
        };
        assert_eq!(k(vec![2, 3]), k(vec![2, 3]));
        assert_ne!(k(vec![2, 3]), k(vec![2, 2]));
        assert_ne!(k(vec![2, 3]), k(vec![2, 3, 3]));
    }

    #[test]
    fn batch_param_guards_check_every_member() {
        let mut param_guards: HashMap<usize, Vec<ElemGuard>> = HashMap::new();
        param_guards.insert(0, vec![ElemGuard { index: 0, expect: 4 }]);
        let plan = BatchPlan {
            steps: Vec::new(),
            param_guards,
            host_guards: HashMap::new(),
            device_peak_bytes: 0,
            memory: None,
            reserve: None,
            launch_elems: 0,
            padded_elems: 0,
        };
        let good = vec![vec![Tensor::i64(&[1], vec![4])], vec![Tensor::i64(&[1], vec![4])]];
        let bad = vec![vec![Tensor::i64(&[1], vec![4])], vec![Tensor::i64(&[1], vec![5])]];
        assert!(plan.param_guards_hold(&good));
        assert!(!plan.param_guards_hold(&bad));
    }

    #[test]
    fn guards_hold_checks_values() {
        let guards = vec![ElemGuard { index: 0, expect: 3 }, ElemGuard { index: 2, expect: 7 }];
        let good = Tensor::i64(&[3], vec![3, 9, 7]);
        let bad = Tensor::i64(&[3], vec![3, 9, 8]);
        let short = Tensor::i64(&[1], vec![3]);
        assert!(host_guards_hold(&guards, &good));
        assert!(!host_guards_hold(&guards, &bad));
        assert!(!host_guards_hold(&guards, &short));
    }
}
