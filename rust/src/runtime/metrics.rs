//! Execution metrics: the quantities behind every table and figure in the
//! paper's evaluation (kernel launches, off-chip bytes, compile events,
//! CPU-vs-device time breakdown).

use std::ops::AddAssign;
use std::time::Duration;

/// Metrics accumulated over one `run` (or a stream of runs, via `+=`).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Memory-intensive device kernel launches (fused + singleton).
    pub mem_kernels: u64,
    /// Compute-intensive library calls (GEMM).
    pub lib_calls: u64,
    /// Host-side ops (shape calculation, index math).
    pub host_ops: u64,
    /// Bitcasts (free reshapes).
    pub bitcasts: u64,
    /// Modeled off-chip bytes moved by memory-intensive kernels
    /// (actual-extent inputs + outputs, once per kernel — fusion saves the
    /// intermediate round-trips).
    pub mem_bytes: u64,
    /// Bytes moved by library calls.
    pub lib_bytes: u64,
    /// FLOPs executed by library calls.
    pub flops: u64,
    /// Kernel-cache misses (compilations triggered by this run).
    pub compile_events: u64,
    /// Time spent compiling kernels during this run.
    pub compile_time: Duration,
    /// Time this run spent *blocked* on the compile service — waiting for
    /// a kernel it triggered itself or joined in flight. Steady-state
    /// replay must keep this at zero: compilation is off the hot path.
    pub compile_stall: Duration,
    /// Single-flight joins: this run missed the shared kernel store while
    /// another worker was already compiling the same (pattern, bucket) key
    /// and waited on that compile instead of duplicating it.
    pub compile_dedup_hits: u64,
    /// Device time inside fused/singleton kernel execution.
    pub kernel_time: Duration,
    /// Device time inside library calls.
    pub lib_time: Duration,
    /// End-to-end wall time of the run.
    pub total_time: Duration,
    /// Pad/crop marshalling copies performed (bucket overhead).
    pub pad_copies: u64,
    /// Buffer-manager events.
    pub allocs: u64,
    pub pool_hits: u64,
    /// Launch-plan cache events: a hit replays the recorded flow (no shape
    /// resolution, no cache hashing); a miss records a new plan; a guard
    /// miss found a stale host-shape assumption and fell back to the
    /// interpreter for that request.
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub plan_guard_misses: u64,
    /// Peak bytes held in device-resident buffers during the run.
    pub device_resident_bytes: u64,
    /// Host→device / device→host transfer payloads. The device-resident
    /// pipeline exists to shrink these on repeat-shape streams.
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// Persistent device-weight cache events: a hit serves a static GEMM
    /// RHS from its resident buffer (zero transfer); a miss pads and
    /// uploads it (once per program in steady state).
    pub weight_cache_hits: u64,
    pub weight_cache_misses: u64,
    /// Bytes of GEMM weights resident on device after the run (a gauge,
    /// not a flow — accumulates as a max).
    pub weight_resident_bytes: u64,
    /// Cross-request batching: requests served through a batched dispatch
    /// (each dispatch covers >= 2 of them)…
    pub batched_requests: u64,
    /// …and the number of such batched dispatches. Solo runs leave both
    /// at zero; the coordinator reports total dispatches separately.
    pub batched_launches: u64,
    /// Pad-lane bytes moved by *batched* launches (the padding waste the
    /// batch-assembly policy trades against launch count).
    pub batch_padding_bytes: u64,
    /// Bytes memcpy'd assembling stacked inputs and splitting per-request
    /// views inside batched dispatches (concat + slice traffic).
    pub batch_stack_bytes: u64,
    /// Batch plan-cache events, folded like the solo plan stats: a hit
    /// replays a recorded stacked walk (no per-step symbol resolution,
    /// no cache hashing, no batching re-analysis); a miss records one; a
    /// guard miss found a stale shape assumption and fell the group back
    /// to the batched interpret tier.
    pub batch_plan_hits: u64,
    pub batch_plan_misses: u64,
    pub batch_plan_guard_misses: u64,
    /// Peak bytes held in device-resident joint buffers during batched
    /// plan replays (a gauge, like `device_resident_bytes`).
    pub batch_dev_resident_bytes: u64,
    /// Planned arena extent of the symbolic memory plan the run replayed
    /// under (a gauge; zero when every replay ran planner-off).
    pub planned_peak_bytes: u64,
    /// Bytes the symbolic memory plan saved versus giving every
    /// device-resident value its own slot — Σ member bytes − planned peak,
    /// summed over planned replays (a flow).
    pub mem_plan_reuse_bytes: u64,
    /// Robustness counters (see `runtime/faults.rs` and the failure-model
    /// section of docs/runtime.md). All flows; zero on fault-free runs.
    ///
    /// Requests dropped by admission control: the producer found the queue
    /// full, or supervision gave up after `max_requeues` worker crashes.
    pub shed_requests: u64,
    /// Requests shed because their deadline expired before dispatch.
    pub deadline_misses: u64,
    /// Compile attempts re-issued after a transient compile failure
    /// (capped exponential backoff, before any demotion).
    pub retries: u64,
    /// Degradation-ladder drops: batch-replay → batch-interpret → solo →
    /// solo replay → interpret → host reference, one count per rung.
    pub demotions: u64,
    /// Workers respawned by the coordinator's supervisor after a panic.
    pub worker_restarts: u64,
    /// Per-tenant circuit-breaker transitions into the Open state (see
    /// `coordinator/tenants.rs`): consecutive dispatch failures crossed the
    /// breaker threshold and the tenant was quarantined.
    pub breaker_trips: u64,
    /// Requests served (or shed) under quarantine while a tenant's breaker
    /// was open — reference-evaluator answers, not replay-tier dispatches.
    pub quarantined: u64,
    /// Autoregressive decode counters (see `runtime/kv.rs` and the decode
    /// section of docs/runtime.md). All flows except `kv_resident_bytes`.
    ///
    /// Decode requests driven to completion through the step loop.
    pub decode_requests: u64,
    /// Individual decode steps executed (one token each).
    pub decode_steps: u64,
    /// KV slab bucket rollovers: the slab outgrew its bucket capacity and
    /// was re-acquired at the next bucket (each one costs exactly one new
    /// plan record; every other step replays the current plan family).
    pub kv_rollovers: u64,
    /// Requests that joined a running decode batch at a step boundary
    /// (iteration-level scheduling; zero for solo decode loops).
    pub decode_joins: u64,
    /// Peak bytes held in KV-cache slabs during the run (a gauge, like
    /// `device_resident_bytes`).
    pub kv_resident_bytes: u64,
    /// Elements actually launched through fused kernels (padded/bucket
    /// extents, inputs + outputs). With `padded_elems` this makes *solo*
    /// padding waste visible — `batch_padding_bytes` only counts the
    /// stacking pad lanes of batched dispatches.
    pub launch_elems: u64,
    /// Of `launch_elems`, the elements that were pure bucket padding
    /// (bucket extent minus actual extent). The padded-FLOP proxy the
    /// traffic-adaptive bucket policy minimizes.
    pub padded_elems: u64,
    /// Bucket-policy epoch the run last dispatched under (a gauge —
    /// folding keeps the max; stays 0 until a re-bucketing swap installs
    /// derived boundaries).
    pub policy_epoch: u64,
    /// Boundary swaps installed on the policy switch so far (a gauge —
    /// every worker sees the same shared switch, so folding takes the max
    /// rather than multiplying the count by the worker pool size).
    pub rebucket_swaps: u64,
    /// Snapshot of the shared per-symbol extent histogram: for each
    /// canonical symbol (by raw id), the sorted `(extent, count)` bins.
    /// Populated by the serve paths when they fold the final report; the
    /// histogram is shared across workers, so folding merges bins by max.
    pub extent_hist: Vec<(u32, Vec<(usize, u64)>)>,
}

impl RunMetrics {
    /// Host-side (CPU) time: everything that is not device kernel/library
    /// execution or compilation — the runtime-flow overhead the paper's
    /// Table 2 "CPU" column measures.
    pub fn cpu_time(&self) -> Duration {
        self.total_time
            .saturating_sub(self.kernel_time)
            .saturating_sub(self.lib_time)
            .saturating_sub(self.compile_time)
    }

    pub fn total_kernels(&self) -> u64 {
        self.mem_kernels + self.lib_calls
    }

    /// Fraction of launched fused-kernel elements that were bucket padding
    /// (0.0 when nothing launched). The quantity the adaptive bucket
    /// policy's gated bench drives down versus the static policy.
    pub fn padding_ratio(&self) -> f64 {
        if self.launch_elems == 0 {
            0.0
        } else {
            self.padded_elems as f64 / self.launch_elems as f64
        }
    }
}

/// Merge two extent-histogram snapshots bin-wise by max: every worker
/// snapshots the *same* shared histogram, so summing would multiply counts
/// by the worker pool size while max keeps the latest (counts are
/// monotone).
fn merge_hist(a: &mut Vec<(u32, Vec<(usize, u64)>)>, b: &[(u32, Vec<(usize, u64)>)]) {
    for (sym, bins) in b {
        match a.iter_mut().find(|(s, _)| s == sym) {
            None => a.push((*sym, bins.clone())),
            Some((_, mine)) => {
                for &(e, c) in bins {
                    match mine.iter_mut().find(|(me, _)| *me == e) {
                        None => mine.push((e, c)),
                        Some((_, mc)) => *mc = (*mc).max(c),
                    }
                }
                mine.sort_unstable_by_key(|&(e, _)| e);
            }
        }
    }
}

impl AddAssign<&RunMetrics> for RunMetrics {
    fn add_assign(&mut self, o: &RunMetrics) {
        self.mem_kernels += o.mem_kernels;
        self.lib_calls += o.lib_calls;
        self.host_ops += o.host_ops;
        self.bitcasts += o.bitcasts;
        self.mem_bytes += o.mem_bytes;
        self.lib_bytes += o.lib_bytes;
        self.flops += o.flops;
        self.compile_events += o.compile_events;
        self.compile_time += o.compile_time;
        self.compile_stall += o.compile_stall;
        self.compile_dedup_hits += o.compile_dedup_hits;
        self.kernel_time += o.kernel_time;
        self.lib_time += o.lib_time;
        self.total_time += o.total_time;
        self.pad_copies += o.pad_copies;
        self.allocs += o.allocs;
        self.pool_hits += o.pool_hits;
        self.plan_hits += o.plan_hits;
        self.plan_misses += o.plan_misses;
        self.plan_guard_misses += o.plan_guard_misses;
        // Residency is a peak, not a flow.
        self.device_resident_bytes = self.device_resident_bytes.max(o.device_resident_bytes);
        self.h2d_bytes += o.h2d_bytes;
        self.d2h_bytes += o.d2h_bytes;
        self.weight_cache_hits += o.weight_cache_hits;
        self.weight_cache_misses += o.weight_cache_misses;
        self.weight_resident_bytes = self.weight_resident_bytes.max(o.weight_resident_bytes);
        self.batched_requests += o.batched_requests;
        self.batched_launches += o.batched_launches;
        self.batch_padding_bytes += o.batch_padding_bytes;
        self.batch_stack_bytes += o.batch_stack_bytes;
        self.batch_plan_hits += o.batch_plan_hits;
        self.batch_plan_misses += o.batch_plan_misses;
        self.batch_plan_guard_misses += o.batch_plan_guard_misses;
        self.batch_dev_resident_bytes =
            self.batch_dev_resident_bytes.max(o.batch_dev_resident_bytes);
        self.planned_peak_bytes = self.planned_peak_bytes.max(o.planned_peak_bytes);
        self.mem_plan_reuse_bytes += o.mem_plan_reuse_bytes;
        self.shed_requests += o.shed_requests;
        self.deadline_misses += o.deadline_misses;
        self.retries += o.retries;
        self.demotions += o.demotions;
        self.worker_restarts += o.worker_restarts;
        self.breaker_trips += o.breaker_trips;
        self.quarantined += o.quarantined;
        self.decode_requests += o.decode_requests;
        self.decode_steps += o.decode_steps;
        self.kv_rollovers += o.kv_rollovers;
        self.decode_joins += o.decode_joins;
        self.kv_resident_bytes = self.kv_resident_bytes.max(o.kv_resident_bytes);
        self.launch_elems += o.launch_elems;
        self.padded_elems += o.padded_elems;
        // Epoch/swap counts and the histogram describe shared state every
        // worker observes — gauges, not flows.
        self.policy_epoch = self.policy_epoch.max(o.policy_epoch);
        self.rebucket_swaps = self.rebucket_swaps.max(o.rebucket_swaps);
        merge_hist(&mut self.extent_hist, &o.extent_hist);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_time_subtracts_device_time() {
        let m = RunMetrics {
            total_time: Duration::from_millis(100),
            kernel_time: Duration::from_millis(30),
            lib_time: Duration::from_millis(20),
            compile_time: Duration::from_millis(10),
            ..Default::default()
        };
        assert_eq!(m.cpu_time(), Duration::from_millis(40));
    }

    #[test]
    fn accumulation() {
        let mut a = RunMetrics { mem_kernels: 3, flops: 10, ..Default::default() };
        let b = RunMetrics { mem_kernels: 4, lib_calls: 2, flops: 5, ..Default::default() };
        a += &b;
        assert_eq!(a.mem_kernels, 7);
        assert_eq!(a.lib_calls, 2);
        assert_eq!(a.total_kernels(), 9);
        assert_eq!(a.flops, 15);
    }

    #[test]
    fn plan_and_transfer_accumulation() {
        let mut a = RunMetrics {
            plan_hits: 1,
            h2d_bytes: 100,
            device_resident_bytes: 400,
            weight_cache_hits: 2,
            weight_resident_bytes: 1000,
            ..Default::default()
        };
        let b = RunMetrics {
            plan_hits: 2,
            plan_misses: 1,
            plan_guard_misses: 1,
            h2d_bytes: 50,
            d2h_bytes: 25,
            device_resident_bytes: 300,
            weight_cache_hits: 3,
            weight_cache_misses: 1,
            weight_resident_bytes: 800,
            ..Default::default()
        };
        a += &b;
        assert_eq!(a.plan_hits, 3);
        assert_eq!(a.compile_dedup_hits, 0);
        assert_eq!(a.batch_plan_hits, 0);
        assert_eq!(a.plan_misses, 1);
        assert_eq!(a.plan_guard_misses, 1);
        assert_eq!(a.h2d_bytes, 150);
        assert_eq!(a.d2h_bytes, 25);
        assert_eq!(a.device_resident_bytes, 400, "residency accumulates as a peak");
        assert_eq!(a.weight_cache_hits, 5);
        assert_eq!(a.weight_cache_misses, 1);
        assert_eq!(a.weight_resident_bytes, 1000, "weight residency is a gauge");
    }

    #[test]
    fn batch_plan_accumulation() {
        let mut a = RunMetrics {
            batch_plan_hits: 1,
            batch_plan_misses: 1,
            batch_dev_resident_bytes: 700,
            ..Default::default()
        };
        let b = RunMetrics {
            batch_plan_hits: 2,
            batch_plan_guard_misses: 1,
            batch_dev_resident_bytes: 500,
            ..Default::default()
        };
        a += &b;
        assert_eq!(a.batch_plan_hits, 3);
        assert_eq!(a.batch_plan_misses, 1);
        assert_eq!(a.batch_plan_guard_misses, 1);
        assert_eq!(a.batch_dev_resident_bytes, 700, "batch residency is a gauge");
    }

    #[test]
    fn memory_plan_counters_fold_gauge_and_flow() {
        let mut a = RunMetrics {
            planned_peak_bytes: 4096,
            mem_plan_reuse_bytes: 1024,
            ..Default::default()
        };
        let b = RunMetrics {
            planned_peak_bytes: 2048,
            mem_plan_reuse_bytes: 512,
            ..Default::default()
        };
        a += &b;
        assert_eq!(a.planned_peak_bytes, 4096, "planned extent is a gauge");
        assert_eq!(a.mem_plan_reuse_bytes, 1536, "reuse savings are a flow");
    }

    #[test]
    fn decode_counters_fold_across_workers() {
        // Flows sum, the slab gauge maxes — folding per-worker decode
        // metrics must neither double-count steps nor sum slab residency.
        let mut a = RunMetrics {
            decode_requests: 1,
            decode_steps: 20,
            kv_rollovers: 1,
            kv_resident_bytes: 40_960,
            ..Default::default()
        };
        let b = RunMetrics {
            decode_requests: 2,
            decode_steps: 35,
            kv_rollovers: 2,
            decode_joins: 1,
            kv_resident_bytes: 24_576,
            ..Default::default()
        };
        a += &b;
        assert_eq!(a.decode_requests, 3);
        assert_eq!(a.decode_steps, 55);
        assert_eq!(a.kv_rollovers, 3);
        assert_eq!(a.decode_joins, 1);
        assert_eq!(a.kv_resident_bytes, 40_960, "slab residency is a gauge");
    }

    #[test]
    fn padding_counters_fold_flows_and_histogram_by_max() {
        let mut a = RunMetrics {
            launch_elems: 100,
            padded_elems: 25,
            policy_epoch: 1,
            rebucket_swaps: 1,
            extent_hist: vec![(0, vec![(9, 5), (40, 2)])],
            ..Default::default()
        };
        let b = RunMetrics {
            launch_elems: 300,
            padded_elems: 15,
            policy_epoch: 1,
            rebucket_swaps: 1,
            extent_hist: vec![(0, vec![(9, 7)]), (1, vec![(4, 1)])],
            ..Default::default()
        };
        a += &b;
        assert_eq!(a.launch_elems, 400, "launched elements are a flow");
        assert_eq!(a.padded_elems, 40, "padded elements are a flow");
        assert!((a.padding_ratio() - 0.1).abs() < 1e-9);
        assert_eq!(a.rebucket_swaps, 1, "shared-switch swap count is a gauge");
        // Histogram bins merge by max: both workers snapshot one shared
        // histogram, so (0, 9) keeps 7, not 12.
        let s0 = &a.extent_hist.iter().find(|(s, _)| *s == 0).unwrap().1;
        assert_eq!(s0.as_slice(), &[(9, 7), (40, 2)]);
        assert!(a.extent_hist.iter().any(|(s, _)| *s == 1));
        assert_eq!(RunMetrics::default().padding_ratio(), 0.0);
    }

    #[test]
    fn robustness_counters_accumulate_as_flows() {
        let mut a = RunMetrics { retries: 1, demotions: 2, ..Default::default() };
        let b = RunMetrics {
            shed_requests: 3,
            deadline_misses: 1,
            retries: 2,
            demotions: 1,
            worker_restarts: 1,
            breaker_trips: 1,
            quarantined: 4,
            ..Default::default()
        };
        a += &b;
        assert_eq!(a.shed_requests, 3);
        assert_eq!(a.deadline_misses, 1);
        assert_eq!(a.retries, 3);
        assert_eq!(a.demotions, 3);
        assert_eq!(a.worker_restarts, 1);
        assert_eq!(a.breaker_trips, 1);
        assert_eq!(a.quarantined, 4);
    }
}
