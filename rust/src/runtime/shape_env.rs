//! Runtime symbol resolution — the executable half of §4.2.1.
//!
//! At compile time every symbolic dimension got a [`ShapeExpr`] definition.
//! At runtime, a [`SymEnv`] binds the entry parameters' concrete extents and
//! evaluates derived symbols on demand (concat sums, dynamic-slice
//! `ceildiv`s, pad widths read out of host shape tensors, …). Data-dependent
//! extents (`Unique`) are pushed in by the kernel that produces them.
//!
//! Binding also *checks* the collected constraints: if two unified dims
//! arrive with different extents the request is rejected — the compile-time
//! constraint was a contract with the frontend.

use crate::dhlo::Module;
use crate::runtime::tensor::Tensor;
use crate::shape::{Dim, ShapeExpr, SymId};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

/// Read access to already-evaluated IR values, abstracted so both the
/// reference interpreter (`Vec<Option<Tensor>>`) and the executor
/// (`Vec<Option<Rc<Tensor>>>`) can drive shape resolution.
pub trait Vals {
    fn tensor(&self, v: usize) -> Option<&Tensor>;
}

impl Vals for [Option<Tensor>] {
    fn tensor(&self, v: usize) -> Option<&Tensor> {
        self.get(v).and_then(|o| o.as_ref())
    }
}

impl Vals for [Option<std::rc::Rc<Tensor>>] {
    fn tensor(&self, v: usize) -> Option<&Tensor> {
        self.get(v).and_then(|o| o.as_deref())
    }
}

/// Empty value store (for resolving shapes that depend only on inputs).
pub struct NoVals;

impl Vals for NoVals {
    fn tensor(&self, _v: usize) -> Option<&Tensor> {
        None
    }
}

/// Concrete values for symbolic dims, keyed by canonical symbol.
#[derive(Debug, Clone, Default)]
pub struct SymEnv {
    vals: HashMap<SymId, i64>,
    /// Concrete dims of each entry parameter (bound once per request).
    param_dims: Vec<Vec<usize>>,
    /// When recording a launch plan, every `Elem` shape read (value id,
    /// element index, observed value) is logged here so the plan can guard
    /// against serving a stale flow when host shape-tensor *contents* (not
    /// just parameter extents) change between requests.
    pub elem_log: Option<Vec<(usize, usize, i64)>>,
}

impl SymEnv {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind the entry parameters' runtime shapes, checking them against the
    /// declared types and the collected dimension-equality constraints.
    pub fn bind_params(&mut self, m: &Module, inputs: &[Tensor]) -> Result<()> {
        ensure!(
            inputs.len() == m.params.len(),
            "expected {} inputs, got {}",
            m.params.len(),
            inputs.len()
        );
        self.param_dims = inputs.iter().map(|t| t.dims.clone()).collect();
        for (p, (ty, t)) in m.params.iter().zip(inputs).enumerate() {
            ensure!(
                ty.dtype == t.dtype,
                "param {p}: dtype mismatch (declared {}, got {:?})",
                ty.dtype,
                t.dtype
            );
            ensure!(
                ty.rank() == t.rank(),
                "param {p}: rank mismatch (declared {}, got {})",
                ty.rank(),
                t.rank()
            );
            for (axis, &d) in ty.dims.iter().enumerate() {
                let actual = t.dims[axis] as i64;
                match m.syms.canon_dim(d) {
                    Dim::Fixed(n) => ensure!(
                        n as i64 == actual,
                        "param {p} axis {axis}: expected {n}, got {actual}"
                    ),
                    Dim::Sym(s) => {
                        if let Some(&prev) = self.vals.get(&s) {
                            ensure!(
                                prev == actual,
                                "constraint violation: param {p} axis {axis} = {actual} \
                                 but a unified dim was already bound to {prev}"
                            );
                        } else {
                            self.vals.insert(s, actual);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Seed a known symbol value (used by the VM baseline, whose runtime
    /// tensor objects carry concrete shapes across per-op shape functions).
    pub fn seed(&mut self, s: SymId, v: i64) {
        self.vals.insert(s, v);
    }

    /// Read access to every resolved symbol binding.
    pub fn resolved(&self) -> &HashMap<SymId, i64> {
        &self.vals
    }

    /// Record a data-dependent extent produced by a kernel (Unique).
    pub fn set_datadep(&mut self, m: &Module, value: usize, n: i64) {
        // Find the symbol whose definition is DataDep{value} and bind its
        // canonical representative.
        for i in 0..m.syms.len() {
            let s = SymId(i as u32);
            if matches!(m.syms.def(s), ShapeExpr::DataDep { value: v } if *v == value) {
                self.vals.insert(m.syms.canon(s), n);
            }
        }
    }

    /// Resolve a dim to its concrete extent. `tensors[v]` must hold the
    /// evaluated tensor for any value the definition reads elements from.
    pub fn resolve_dim(
        &mut self,
        m: &Module,
        d: Dim,
        tensors: &(impl Vals + ?Sized),
    ) -> Result<usize> {
        match m.syms.canon_dim(d) {
            Dim::Fixed(n) => Ok(n),
            Dim::Sym(s) => {
                if let Some(&v) = self.vals.get(&s) {
                    return Ok(v as usize);
                }
                let def = m.syms.def(s).clone();
                let v = self
                    .eval_expr(m, &def, tensors)
                    .with_context(|| format!("resolving {} := {}", s, def))?;
                ensure!(v >= 0, "negative extent {v} for {s}");
                self.vals.insert(s, v);
                Ok(v as usize)
            }
        }
    }

    /// Resolve a full dim vector.
    pub fn resolve_dims(
        &mut self,
        m: &Module,
        dims: &[Dim],
        tensors: &(impl Vals + ?Sized),
    ) -> Result<Vec<usize>> {
        dims.iter().map(|&d| self.resolve_dim(m, d, tensors)).collect()
    }

    /// Evaluate a shape expression against the current bindings.
    pub fn eval_expr(
        &mut self,
        m: &Module,
        e: &ShapeExpr,
        tensors: &(impl Vals + ?Sized),
    ) -> Result<i64> {
        Ok(match e {
            ShapeExpr::Const(c) => *c,
            ShapeExpr::InputDim { param, axis } => {
                let dims = self
                    .param_dims
                    .get(*param)
                    .with_context(|| format!("input dim of unbound param {param}"))?;
                ensure!(*axis < dims.len(), "input-dim axis out of range");
                dims[*axis] as i64
            }
            ShapeExpr::Dim(d) => self.resolve_dim(m, *d, tensors)? as i64,
            ShapeExpr::Elem { value, index } => {
                let t = tensors
                    .tensor(*value)
                    .with_context(|| format!("shape tensor %{value} not evaluated yet"))?;
                let v = t.as_i64()?;
                ensure!(*index < v.len(), "shape tensor index out of range");
                let read = v[*index];
                if let Some(log) = self.elem_log.as_mut() {
                    log.push((*value, *index, read));
                }
                read
            }
            ShapeExpr::DataDep { value } => {
                bail!("data-dependent extent of %{value} not yet produced")
            }
            ShapeExpr::Add(a, b) => self.eval_expr(m, a, tensors)? + self.eval_expr(m, b, tensors)?,
            ShapeExpr::Sub(a, b) => self.eval_expr(m, a, tensors)? - self.eval_expr(m, b, tensors)?,
            ShapeExpr::Mul(a, b) => self.eval_expr(m, a, tensors)? * self.eval_expr(m, b, tensors)?,
            ShapeExpr::CeilDiv(a, b) => {
                let (x, y) = (self.eval_expr(m, a, tensors)?, self.eval_expr(m, b, tensors)?);
                ensure!(y > 0, "ceildiv by non-positive {y}");
                (x + y - 1) / y
            }
            ShapeExpr::Max(a, b) => {
                self.eval_expr(m, a, tensors)?.max(self.eval_expr(m, b, tensors)?)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType};
    use crate::shape::Dim;

    #[test]
    fn binds_and_checks_params() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let _ = x;
        let m = b.finish(vec![x]);
        let mut env = SymEnv::new();
        env.bind_params(&m, &[Tensor::f32(&[3, 4], vec![0.0; 12])]).unwrap();
        let mut env2 = SymEnv::new();
        // Wrong fixed dim rejected.
        assert!(env2.bind_params(&m, &[Tensor::f32(&[3, 5], vec![0.0; 15])]).is_err());
    }

    #[test]
    fn unified_dims_must_agree_at_runtime() {
        let mut b = Builder::new("t");
        let s1 = b.dyn_dim("a", 0, 0);
        let x = b.param(DType::F32, vec![s1]);
        let s2 = b.dyn_dim("b", 1, 0);
        let y = b.param(DType::F32, vec![s2]);
        let z = b.add(x, y).unwrap(); // unifies s1, s2
        let m = b.finish(vec![z]);
        let mut env = SymEnv::new();
        let ok = env.bind_params(
            &m,
            &[Tensor::f32(&[3], vec![0.; 3]), Tensor::f32(&[3], vec![0.; 3])],
        );
        assert!(ok.is_ok());
        let mut env2 = SymEnv::new();
        let bad = env2.bind_params(
            &m,
            &[Tensor::f32(&[3], vec![0.; 3]), Tensor::f32(&[4], vec![0.; 4])],
        );
        assert!(bad.is_err(), "constraint violation must be rejected");
    }

    #[test]
    fn derived_symbol_evaluation() {
        let mut b = Builder::new("t");
        let s1 = b.dyn_dim("a", 0, 0);
        let x = b.param(DType::F32, vec![s1, Dim::Fixed(2)]);
        let s2 = b.dyn_dim("b", 1, 0);
        let y = b.param(DType::F32, vec![s2, Dim::Fixed(2)]);
        let c = b.concat(&[x, y], 0).unwrap();
        let m = b.finish(vec![c]);
        let mut env = SymEnv::new();
        env.bind_params(
            &m,
            &[Tensor::f32(&[3, 2], vec![0.; 6]), Tensor::f32(&[5, 2], vec![0.; 10])],
        )
        .unwrap();
        let dims = env.resolve_dims(&m, &m.ty(c).dims.clone(), &NoVals).unwrap();
        assert_eq!(dims, vec![8, 2]);
    }

    #[test]
    fn elem_reads_host_tensor() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let st = b.i64_vec(&[1]);
        let li = b.i64_vec(&[5]);
        let sr = b.i64_vec(&[2]);
        let sl = b.dslice(x, st, li, sr).unwrap();
        let m = b.finish(vec![sl]);
        let mut env = SymEnv::new();
        env.bind_params(&m, &[Tensor::f32(&[8], vec![0.; 8])]).unwrap();
        // Provide the evaluated index tensors at their value slots.
        let mut tensors: Vec<Option<Tensor>> = vec![None; m.instrs.len()];
        tensors[st] = Some(Tensor::i64(&[1], vec![1]));
        tensors[li] = Some(Tensor::i64(&[1], vec![5]));
        tensors[sr] = Some(Tensor::i64(&[1], vec![2]));
        let dims = env.resolve_dims(&m, &m.ty(sl).dims.clone(), &tensors[..]).unwrap();
        assert_eq!(dims, vec![2]); // ceil((5-1)/2) = 2
    }
}
