//! Reference interpreter: per-op concrete evaluation over host tensors.
//!
//! Three consumers:
//! 1. the **numerics oracle** integration tests compare every backend
//!    against;
//! 2. the **framework-eager baseline** (`Mode::Eager`): one pre-built kernel
//!    per op, launched one-by-one — exactly how TF/PyTorch execute the
//!    memory-intensive portion of a graph;
//! 3. **constant folding** inside the pass pipeline.

use crate::dhlo::{BinKind, CmpDir, DType, Module, Op, ReduceKind, UnKind};
use crate::runtime::shape_env::SymEnv;
use crate::runtime::tensor::{ravel, strides_of, unravel, Data, Tensor};
use anyhow::{bail, ensure, Context, Result};

/// Abramowitz–Stegun 7.1.26 rational approximation of erf (|err| < 1.5e-7),
/// matching XLA's f32 erf to well within test tolerances.
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + erf(x / std::f32::consts::SQRT_2))
}

/// Evaluate a unary elementwise op.
pub fn eval_unary(k: UnKind, x: &Tensor) -> Result<Tensor> {
    match &x.data {
        Data::F32(v) => {
            let f: fn(f32) -> f32 = match k {
                UnKind::Abs => f32::abs,
                UnKind::Neg => |a| -a,
                UnKind::Exp => f32::exp,
                UnKind::Log => f32::ln,
                UnKind::Tanh => f32::tanh,
                UnKind::Sqrt => f32::sqrt,
                UnKind::Rsqrt => |a| 1.0 / a.sqrt(),
                UnKind::Sigmoid => |a| 1.0 / (1.0 + (-a).exp()),
                UnKind::Relu => |a| a.max(0.0),
                UnKind::Gelu => gelu,
                UnKind::Erf => erf,
                UnKind::Floor => f32::floor,
                UnKind::Sign => |a| {
                    if a > 0.0 {
                        1.0
                    } else if a < 0.0 {
                        -1.0
                    } else {
                        0.0
                    }
                },
            };
            Ok(Tensor::f32(&x.dims, v.iter().map(|&a| f(a)).collect()))
        }
        Data::I64(v) => {
            let f: fn(i64) -> i64 = match k {
                UnKind::Abs => i64::abs,
                UnKind::Neg => |a| -a,
                UnKind::Sign => i64::signum,
                _ => bail!("unary {k:?} unsupported for i64"),
            };
            Ok(Tensor::i64(&x.dims, v.iter().map(|&a| f(a)).collect()))
        }
        _ => bail!("unary {k:?} unsupported for {:?}", x.dtype),
    }
}

/// Evaluate a binary elementwise op (shapes must match exactly; DHLO makes
/// broadcasts explicit).
pub fn eval_binary(k: BinKind, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    ensure!(a.dims == b.dims, "binary {k:?}: shape mismatch {:?} vs {:?}", a.dims, b.dims);
    match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            let f: fn(f32, f32) -> f32 = match k {
                BinKind::Add => |p, q| p + q,
                BinKind::Sub => |p, q| p - q,
                BinKind::Mul => |p, q| p * q,
                BinKind::Div => |p, q| p / q,
                BinKind::Max => f32::max,
                BinKind::Min => f32::min,
                BinKind::Pow => f32::powf,
            };
            Ok(Tensor::f32(&a.dims, x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect()))
        }
        (Data::I64(x), Data::I64(y)) => {
            let f: fn(i64, i64) -> i64 = match k {
                BinKind::Add => |p, q| p + q,
                BinKind::Sub => |p, q| p - q,
                BinKind::Mul => |p, q| p * q,
                BinKind::Div => |p, q| p / q,
                BinKind::Max => i64::max,
                BinKind::Min => i64::min,
                BinKind::Pow => bail!("pow unsupported for i64"),
            };
            Ok(Tensor::i64(&a.dims, x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect()))
        }
        _ => bail!("binary {k:?}: dtype mismatch"),
    }
}

fn eval_compare(dir: CmpDir, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    ensure!(a.dims == b.dims, "compare: shape mismatch");
    let cmp = |o: std::cmp::Ordering| match dir {
        CmpDir::Eq => o == std::cmp::Ordering::Equal,
        CmpDir::Ne => o != std::cmp::Ordering::Equal,
        CmpDir::Lt => o == std::cmp::Ordering::Less,
        CmpDir::Le => o != std::cmp::Ordering::Greater,
        CmpDir::Gt => o == std::cmp::Ordering::Greater,
        CmpDir::Ge => o != std::cmp::Ordering::Less,
    };
    let out: Vec<bool> = match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => x
            .iter()
            .zip(y)
            .map(|(p, q)| cmp(p.partial_cmp(q).unwrap_or(std::cmp::Ordering::Greater)))
            .collect(),
        (Data::I64(x), Data::I64(y)) => x.iter().zip(y).map(|(p, q)| cmp(p.cmp(q))).collect(),
        _ => bail!("compare: dtype mismatch"),
    };
    Ok(Tensor::pred(&a.dims, out))
}

fn eval_select(p: &Tensor, t: &Tensor, f: &Tensor) -> Result<Tensor> {
    ensure!(p.dims == t.dims && t.dims == f.dims, "select: shape mismatch");
    let pv = p.as_pred()?;
    match (&t.data, &f.data) {
        (Data::F32(x), Data::F32(y)) => Ok(Tensor::f32(
            &t.dims,
            pv.iter().zip(x.iter().zip(y)).map(|(&c, (&a, &b))| if c { a } else { b }).collect(),
        )),
        (Data::I64(x), Data::I64(y)) => Ok(Tensor::i64(
            &t.dims,
            pv.iter().zip(x.iter().zip(y)).map(|(&c, (&a, &b))| if c { a } else { b }).collect(),
        )),
        _ => bail!("select: dtype mismatch"),
    }
}

fn eval_convert(x: &Tensor, to: DType) -> Result<Tensor> {
    let n = x.elems();
    Ok(match (to, &x.data) {
        (DType::F32, Data::I64(v)) => Tensor::f32(&x.dims, v.iter().map(|&a| a as f32).collect()),
        (DType::F32, Data::I32(v)) => Tensor::f32(&x.dims, v.iter().map(|&a| a as f32).collect()),
        (DType::F32, Data::Pred(v)) => {
            Tensor::f32(&x.dims, v.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect())
        }
        (DType::I64, Data::F32(v)) => Tensor::i64(&x.dims, v.iter().map(|&a| a as i64).collect()),
        (DType::I64, Data::I32(v)) => Tensor::i64(&x.dims, v.iter().map(|&a| a as i64).collect()),
        (DType::I32, Data::I64(v)) => Tensor::i32(&x.dims, v.iter().map(|&a| a as i32).collect()),
        (DType::I32, Data::F32(v)) => Tensor::i32(&x.dims, v.iter().map(|&a| a as i32).collect()),
        (t, _) if t == x.dtype => x.clone(),
        _ => bail!("convert {:?} -> {to:?} unsupported ({n} elems)", x.dtype),
    })
}

fn eval_broadcast(x: &Tensor, mapping: &[usize], out_dims: &[usize]) -> Result<Tensor> {
    let in_strides = x.strides();
    let total: usize = out_dims.iter().product();
    let fetch = |out_lin: usize| -> usize {
        let coord = unravel(out_lin, out_dims);
        let mut in_idx = 0usize;
        for (i, &m) in mapping.iter().enumerate() {
            let c = if x.dims[i] == 1 { 0 } else { coord[m] };
            in_idx += c * in_strides[i];
        }
        in_idx
    };
    Ok(match &x.data {
        Data::F32(v) => Tensor::f32(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::I64(v) => Tensor::i64(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::I32(v) => Tensor::i32(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::Pred(v) => Tensor::pred(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
    })
}

fn eval_transpose(x: &Tensor, perm: &[usize]) -> Result<Tensor> {
    let out_dims: Vec<usize> = perm.iter().map(|&p| x.dims[p]).collect();
    let in_strides = x.strides();
    let total = x.elems();
    let fetch = |out_lin: usize| -> usize {
        let coord = unravel(out_lin, &out_dims);
        let mut idx = 0;
        for (o, &p) in perm.iter().enumerate() {
            idx += coord[o] * in_strides[p];
        }
        idx
    };
    Ok(match &x.data {
        Data::F32(v) => Tensor::f32(&out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::I64(v) => Tensor::i64(&out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::I32(v) => Tensor::i32(&out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::Pred(v) => Tensor::pred(&out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
    })
}

fn eval_concat(xs: &[&Tensor], axis: usize, out_dims: &[usize]) -> Result<Tensor> {
    let mut out = Tensor::zeros(xs[0].dtype, out_dims);
    let out_strides = strides_of(out_dims);
    let mut offset = 0usize;
    for x in xs {
        let total = x.elems();
        for lin in 0..total {
            let mut coord = unravel(lin, &x.dims);
            coord[axis] += offset;
            let out_lin = ravel(&coord, &out_strides);
            copy_elem(x, lin, &mut out, out_lin)?;
        }
        offset += x.dims[axis];
    }
    Ok(out)
}

fn copy_elem(src: &Tensor, si: usize, dst: &mut Tensor, di: usize) -> Result<()> {
    match (&src.data, &mut dst.data) {
        (Data::F32(s), Data::F32(d)) => d[di] = s[si],
        (Data::I64(s), Data::I64(d)) => d[di] = s[si],
        (Data::I32(s), Data::I32(d)) => d[di] = s[si],
        (Data::Pred(s), Data::Pred(d)) => d[di] = s[si],
        _ => bail!("copy_elem dtype mismatch"),
    }
    Ok(())
}

fn eval_slice(x: &Tensor, starts: &[i64], strides: &[i64], out_dims: &[usize]) -> Result<Tensor> {
    let in_strides = x.strides();
    let total: usize = out_dims.iter().product();
    let fetch = |out_lin: usize| -> usize {
        let coord = unravel(out_lin, out_dims);
        coord
            .iter()
            .enumerate()
            .map(|(i, &c)| (starts[i] as usize + c * strides[i] as usize) * in_strides[i])
            .sum()
    };
    Ok(match &x.data {
        Data::F32(v) => Tensor::f32(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::I64(v) => Tensor::i64(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::I32(v) => Tensor::i32(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
        Data::Pred(v) => Tensor::pred(out_dims, (0..total).map(|i| v[fetch(i)]).collect()),
    })
}

fn eval_pad(x: &Tensor, value: &Tensor, low: &[i64], out_dims: &[usize]) -> Result<Tensor> {
    let mut out = match &value.data {
        Data::F32(v) => Tensor::f32(out_dims, vec![v[0]; out_dims.iter().product()]),
        Data::I64(v) => Tensor::i64(out_dims, vec![v[0]; out_dims.iter().product()]),
        Data::I32(v) => Tensor::i32(out_dims, vec![v[0]; out_dims.iter().product()]),
        Data::Pred(v) => Tensor::pred(out_dims, vec![v[0]; out_dims.iter().product()]),
    };
    let out_strides = strides_of(out_dims);
    for lin in 0..x.elems() {
        let coord = unravel(lin, &x.dims);
        let out_lin: usize = coord
            .iter()
            .enumerate()
            .map(|(i, &c)| (c + low[i] as usize) * out_strides[i])
            .sum();
        copy_elem(x, lin, &mut out, out_lin)?;
    }
    Ok(out)
}

fn eval_reduce(kind: ReduceKind, x: &Tensor, axes: &[usize], out_dims: &[usize]) -> Result<Tensor> {
    let v = x.as_f32().context("reduce: f32 only")?;
    let out_strides = strides_of(out_dims);
    let init = kind.neutral();
    let mut acc = vec![init; out_dims.iter().product::<usize>().max(1)];
    for lin in 0..x.elems() {
        let coord = unravel(lin, &x.dims);
        let out_coord: Vec<usize> = coord
            .iter()
            .enumerate()
            .filter(|(i, _)| !axes.contains(i))
            .map(|(_, &c)| c)
            .collect();
        let oi = ravel(&out_coord, &out_strides);
        acc[oi] = match kind {
            ReduceKind::Sum | ReduceKind::Mean => acc[oi] + v[lin],
            ReduceKind::Max => acc[oi].max(v[lin]),
            ReduceKind::Min => acc[oi].min(v[lin]),
        };
    }
    if kind == ReduceKind::Mean {
        let denom: usize = axes.iter().map(|&a| x.dims[a]).product();
        for a in acc.iter_mut() {
            *a /= denom as f32;
        }
    }
    Ok(Tensor::f32(out_dims, acc))
}

fn eval_dot(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (av, bv) = (a.as_f32()?, b.as_f32()?);
    match (a.rank(), b.rank()) {
        (2, 2) => {
            let (m, k) = (a.dims[0], a.dims[1]);
            let n = b.dims[1];
            ensure!(b.dims[0] == k, "dot: contracting mismatch");
            let mut out = vec![0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let x = av[i * k + kk];
                    if x == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        out[i * n + j] += x * bv[kk * n + j];
                    }
                }
            }
            Ok(Tensor::f32(&[m, n], out))
        }
        (3, 3) => {
            let (bsz, m, k) = (a.dims[0], a.dims[1], a.dims[2]);
            let n = b.dims[2];
            ensure!(b.dims[0] == bsz && b.dims[1] == k, "batched dot: shape mismatch");
            let mut out = vec![0f32; bsz * m * n];
            for bb in 0..bsz {
                let (ao, bo, oo) = (bb * m * k, bb * k * n, bb * m * n);
                for i in 0..m {
                    for kk in 0..k {
                        let x = av[ao + i * k + kk];
                        if x == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            out[oo + i * n + j] += x * bv[bo + kk * n + j];
                        }
                    }
                }
            }
            Ok(Tensor::f32(&[bsz, m, n], out))
        }
        _ => bail!("dot: unsupported ranks"),
    }
}

fn eval_gather(x: &Tensor, idx: &Tensor, axis: usize, out_dims: &[usize]) -> Result<Tensor> {
    let iv = idx.as_i64()?;
    let in_strides = x.strides();
    let total: usize = out_dims.iter().product();
    let fetch = |out_lin: usize| -> Result<usize> {
        let coord = unravel(out_lin, out_dims);
        let mut idx_sum = 0usize;
        for (i, &c) in coord.iter().enumerate() {
            let c_in = if i == axis {
                let j = iv[c];
                ensure!(j >= 0 && (j as usize) < x.dims[axis], "gather index {j} out of range");
                j as usize
            } else {
                c
            };
            idx_sum += c_in * in_strides[i];
        }
        Ok(idx_sum)
    };
    let mut out = Tensor::zeros(x.dtype, out_dims);
    for lin in 0..total {
        let src = fetch(lin)?;
        copy_elem(x, src, &mut out, lin)?;
    }
    Ok(out)
}

fn eval_iota(dtype: DType, out_dims: &[usize], axis: usize) -> Result<Tensor> {
    let total: usize = out_dims.iter().product();
    let vals: Vec<usize> = (0..total).map(|lin| unravel(lin, out_dims)[axis]).collect();
    Ok(match dtype {
        DType::F32 => Tensor::f32(out_dims, vals.iter().map(|&v| v as f32).collect()),
        DType::I64 => Tensor::i64(out_dims, vals.iter().map(|&v| v as i64).collect()),
        DType::I32 => Tensor::i32(out_dims, vals.iter().map(|&v| v as i32).collect()),
        DType::Pred => bail!("iota: pred unsupported"),
    })
}

fn eval_unique(x: &Tensor) -> Result<Tensor> {
    let v = x.as_i64()?;
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for &e in v {
        if seen.insert(e) {
            out.push(e);
        }
    }
    let n = out.len();
    Ok(Tensor::i64(&[n], out))
}

/// Evaluate one non-Param/Const op over concrete operand tensors.
/// `out_dims` must be the already-resolved concrete output dims and
/// `out_dtype` the instruction's element type.
pub fn eval_op(
    op: &Op,
    operands: &[&Tensor],
    out_dims: &[usize],
    out_dtype: DType,
) -> Result<Tensor> {
    match op {
        Op::Param { .. } | Op::Const { .. } => bail!("handled by caller"),
        Op::Un(k) => eval_unary(*k, operands[0]),
        Op::Bin(k) => eval_binary(*k, operands[0], operands[1]),
        Op::Cmp(d) => eval_compare(*d, operands[0], operands[1]),
        Op::Select => eval_select(operands[0], operands[1], operands[2]),
        Op::Convert(t) => eval_convert(operands[0], *t),
        Op::Broadcast { dims } | Op::DBroadcast { dims } => {
            eval_broadcast(operands[0], dims, out_dims)
        }
        Op::Transpose { perm } => eval_transpose(operands[0], perm),
        Op::Reshape | Op::DReshape => operands[0].clone().with_dims(out_dims),
        Op::Concat { axis } => eval_concat(operands, *axis, out_dims),
        Op::Slice { starts, strides, .. } => eval_slice(operands[0], starts, strides, out_dims),
        Op::DSlice => {
            let starts = operands[1].as_i64()?.to_vec();
            let strides = operands[3].as_i64()?.to_vec();
            eval_slice(operands[0], &starts, &strides, out_dims)
        }
        Op::Pad { low, .. } => eval_pad(operands[0], operands[1], low, out_dims),
        Op::DPad => {
            let low = operands[2].as_i64()?.to_vec();
            eval_pad(operands[0], operands[1], &low, out_dims)
        }
        Op::Reduce { kind, axes } => eval_reduce(*kind, operands[0], axes, out_dims),
        Op::Dot => eval_dot(operands[0], operands[1]),
        Op::Gather { axis } => eval_gather(operands[0], operands[1], *axis, out_dims),
        Op::Iota { axis } => eval_iota(out_dtype, out_dims, *axis),
        Op::Unique => eval_unique(operands[0]),
        Op::GetDimSize { axis } => Ok(Tensor::scalar_i64(operands[0].dims[*axis] as i64)),
    }
}

/// Full-module reference evaluation. Also returns the number of "kernel
/// launches" (one per non-Param/Const instruction), which is what the eager
/// baseline's launch counter reports.
pub struct EvalResult {
    pub outputs: Vec<Tensor>,
    pub launches: usize,
    /// Total bytes read+written by memory-intensive ops (off-chip traffic
    /// model for the eager baseline).
    pub bytes_moved: usize,
}

pub fn eval_module(m: &Module, inputs: &[Tensor]) -> Result<EvalResult> {
    let mut env = SymEnv::new();
    env.bind_params(m, inputs)?;
    let mut vals: Vec<Option<Tensor>> = vec![None; m.instrs.len()];
    let mut launches = 0usize;
    let mut bytes_moved = 0usize;

    for (id, ins) in m.instrs.iter().enumerate() {
        let t = match &ins.op {
            Op::Param { index } => inputs[*index].clone(),
            Op::Const { lit, dims } => Tensor::from_literal(lit, dims),
            Op::Unique => {
                let x = vals[ins.operands[0]].as_ref().unwrap();
                let u = eval_unique(x)?;
                env.set_datadep(m, id, u.dims[0] as i64);
                launches += 1;
                bytes_moved += x.byte_size() + u.byte_size();
                u
            }
            op => {
                let out_dims = env
                    .resolve_dims(m, &ins.ty.dims, &vals[..])
                    .with_context(|| format!("resolving output dims of %{id} ({})", op.name()))?;
                let operands: Vec<&Tensor> =
                    ins.operands.iter().map(|&o| vals[o].as_ref().unwrap()).collect();
                launches += 1;
                for o in &operands {
                    bytes_moved += o.byte_size();
                }
                let t = eval_op(op, &operands, &out_dims, ins.ty.dtype)?;
                bytes_moved += t.byte_size();
                t
            }
        };
        vals[id] = Some(t);
    }

    let outputs = m.outputs.iter().map(|&o| vals[o].clone().unwrap()).collect();
    Ok(EvalResult { outputs, launches, bytes_moved })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::Builder;
    use crate::shape::Dim;

    #[test]
    fn elementwise_chain() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let e = b.unary(UnKind::Exp, x);
        let y = b.add(x, e).unwrap();
        let m = b.finish(vec![y]);
        let r = eval_module(&m, &[Tensor::f32(&[3], vec![0.0, 1.0, -1.0])]).unwrap();
        let out = r.outputs[0].as_f32().unwrap();
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!((out[1] - (1.0 + 1f32.exp())).abs() < 1e-6);
        assert_eq!(r.launches, 2);
    }

    #[test]
    fn softmax_matches_manual() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(3)]);
        let y = b.softmax_last(x).unwrap();
        let m = b.finish(vec![y]);
        let input = Tensor::f32(&[2, 3], vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let r = eval_module(&m, &[input]).unwrap();
        let out = r.outputs[0].as_f32().unwrap();
        // Row sums are 1.
        assert!((out[0] + out[1] + out[2] - 1.0).abs() < 1e-6);
        assert!((out[3] - 1.0 / 3.0).abs() < 1e-6);
        // Monotone in logits.
        assert!(out[2] > out[1] && out[1] > out[0]);
    }

    #[test]
    fn dot_2d_and_batched() {
        let a = Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::f32(&[2, 2], vec![1., 1., 1., 1.]);
        let r = eval_dot(&a, &b).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[3., 3., 7., 7.]);
        let a3 = Tensor::f32(&[1, 2, 2], vec![1., 2., 3., 4.]);
        let b3 = Tensor::f32(&[1, 2, 2], vec![1., 0., 0., 1.]);
        let r3 = eval_dot(&a3, &b3).unwrap();
        assert_eq!(r3.as_f32().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn dynamic_slice_via_tensors() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let st = b.i64_vec(&[1]);
        let li = b.i64_vec(&[4]);
        let sr = b.i64_vec(&[1]);
        let sl = b.dslice(x, st, li, sr).unwrap();
        let m = b.finish(vec![sl]);
        let r = eval_module(&m, &[Tensor::f32(&[6], vec![0., 1., 2., 3., 4., 5.])]).unwrap();
        assert_eq!(r.outputs[0].as_f32().unwrap(), &[1., 2., 3.]);
    }

    #[test]
    fn unique_data_dependent_shape() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::I64, vec![s]);
        let u = b.unique(x).unwrap();
        // Consumer that depends on the data-dependent shape.
        let g = b.unary(UnKind::Neg, u);
        let m = b.finish(vec![g]);
        let r = eval_module(&m, &[Tensor::i64(&[6], vec![3, 1, 3, 2, 1, 3])]).unwrap();
        assert_eq!(r.outputs[0].as_i64().unwrap(), &[-3, -1, -2]);
    }

    #[test]
    fn pad_and_concat() {
        let mut b = Builder::new("t");
        let x = b.param(DType::F32, vec![Dim::Fixed(2)]);
        let z = b.scalar_f32(9.0);
        let p = b.pad(x, z, vec![1], vec![2]).unwrap();
        let c = b.concat(&[p, x], 0).unwrap();
        let m = b.finish(vec![c]);
        let r = eval_module(&m, &[Tensor::f32(&[2], vec![1., 2.])]).unwrap();
        assert_eq!(r.outputs[0].as_f32().unwrap(), &[9., 1., 2., 9., 9., 1., 2.]);
    }

    #[test]
    fn reduce_kinds() {
        let x = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let sum = eval_reduce(ReduceKind::Sum, &x, &[1], &[2]).unwrap();
        assert_eq!(sum.as_f32().unwrap(), &[6., 15.]);
        let mx = eval_reduce(ReduceKind::Max, &x, &[0], &[3]).unwrap();
        assert_eq!(mx.as_f32().unwrap(), &[4., 5., 6.]);
        let mean = eval_reduce(ReduceKind::Mean, &x, &[0, 1], &[]).unwrap();
        assert!((mean.as_f32().unwrap()[0] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn gather_rows() {
        let mut b = Builder::new("t");
        let table = b.param(DType::F32, vec![Dim::Fixed(4), Dim::Fixed(2)]);
        let n = b.dyn_dim("n", 1, 0);
        let idx = b.param(DType::I64, vec![n]);
        let g = b.gather(table, idx, 0).unwrap();
        let m = b.finish(vec![g]);
        let r = eval_module(
            &m,
            &[
                Tensor::f32(&[4, 2], vec![0., 0., 1., 1., 2., 2., 3., 3.]),
                Tensor::i64(&[3], vec![2, 0, 3]),
            ],
        )
        .unwrap();
        assert_eq!(r.outputs[0].as_f32().unwrap(), &[2., 2., 0., 0., 3., 3.]);
    }

    #[test]
    fn erf_accuracy() {
        // Known values.
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427008).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427008).abs() < 1e-5);
        assert!((erf(3.0) - 0.9999779).abs() < 1e-5);
    }

    #[test]
    fn transpose_layernorm_pipeline() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let g = b.param(DType::F32, vec![Dim::Fixed(4)]);
        let be = b.param(DType::F32, vec![Dim::Fixed(4)]);
        let ln = b.layernorm_last(x, g, be, 1e-5).unwrap();
        let m = b.finish(vec![ln]);
        let r = eval_module(
            &m,
            &[
                Tensor::f32(&[2, 4], vec![1., 2., 3., 4., -1., -2., -3., -4.]),
                Tensor::f32(&[4], vec![1.; 4]),
                Tensor::f32(&[4], vec![0.; 4]),
            ],
        )
        .unwrap();
        let out = r.outputs[0].as_f32().unwrap();
        // Each row should be mean ~0, var ~1.
        let row0: f32 = out[..4].iter().sum();
        assert!(row0.abs() < 1e-4);
        let var0: f32 = out[..4].iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var0 - 1.0).abs() < 1e-2);
    }
}
