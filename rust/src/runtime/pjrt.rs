//! PJRT device wrapper: compile HLO text, execute with host tensors or
//! device-resident buffers.
//!
//! This is the "device side" of the reproduction. Fused kernels emitted by
//! `codegen` (HLO text, exactly the interchange format the AOT pipeline
//! uses) are compiled once per (pattern, bucket) and then executed from the
//! hot path with zero Python involvement.
//!
//! Two execution paths exist:
//!
//! * [`Executable::run`] — the host path: marshal host tensors into
//!   literals, execute, synchronously read the result back. One H2D copy
//!   per operand and one D2H per launch.
//! * [`Executable::run_on_device`] — the device-resident path used by
//!   cached launch plans and the GEMM library's buffer-resident entry
//!   points: operands are [`DeviceTensor`]s (PJRT buffers), the result
//!   *stays on device*, and only plan boundaries (program outputs, host-op
//!   operands) pay a readback. The library's cached weights and its
//!   on-device bucket adapters run entirely through this path, so a
//!   steady-state GEMM moves zero host↔device payload.

use crate::dhlo::DType;
use crate::runtime::faults::{self, FaultPlan, FaultSite};
use crate::runtime::tensor::{Data, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Distinguishes temp workspaces of multiple devices within one process.
static WORKSPACE_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A per-device scratch directory for HLO temp files. The bundled XLA
/// exposes only a file parser, so `compile_hlo_text` must round-trip
/// through disk; keeping every file in one per-process subdirectory (with
/// the kernel name in the filename for debuggability) and removing the
/// whole directory on `Drop` fixes the unbounded `/tmp` churn the previous
/// flat-file scheme produced.
struct TempWorkspace {
    dir: PathBuf,
    counter: AtomicU64,
}

impl TempWorkspace {
    fn new() -> Result<TempWorkspace> {
        let seq = WORKSPACE_COUNTER.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("disc_hlo_{}_{seq}", std::process::id()));
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating HLO temp dir {}", dir.display()))?;
        Ok(TempWorkspace { dir, counter: AtomicU64::new(0) })
    }

    /// Unique path for one HLO module, carrying a sanitized kernel name.
    fn file_for(&self, name: &str) -> PathBuf {
        let clean: String = name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '-' { c } else { '_' })
            .take(48)
            .collect();
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        self.dir.join(format!("{n:05}_{clean}.hlo.txt"))
    }
}

impl Drop for TempWorkspace {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// A PJRT device (CPU in this testbed; the same wrapper would target GPU).
///
/// The device is `Send + Sync`: one `Arc<Device>` is shared by every
/// executor worker, the process-wide kernel store, and the background
/// compile pool. Stats live behind a `Mutex` (they are tiny counters; the
/// lock is held for a handful of adds).
pub struct Device {
    client: xla::PjRtClient,
    temp: TempWorkspace,
    stats: std::sync::Mutex<DeviceStats>,
    /// Fault-injection schedule captured at construction (`DISC_FAULTS` by
    /// default). `None` — the production configuration — costs one branch
    /// per seam; see `runtime/faults.rs`.
    faults: Option<Arc<FaultPlan>>,
}

/// Compile-time proof that the runtime types may cross threads: the
/// multi-worker coordinator moves executors (holding `Arc<Device>`,
/// `Arc<Executable>`, device tensors) into worker threads, and the
/// background compile pool compiles on its own threads.
const _: fn() = || {
    fn ok<T: Send + Sync>() {}
    ok::<Device>();
    ok::<Executable>();
    ok::<DeviceTensor>();
};

/// Compilation + transfer statistics a device accumulates (feeds the
/// compile-overhead bench and the CPU-time breakdown).
#[derive(Debug, Default, Clone)]
pub struct DeviceStats {
    pub compilations: u64,
    pub compile_time: std::time::Duration,
    /// Host→device transfers (count and payload bytes).
    pub h2d_transfers: u64,
    pub h2d_bytes: u64,
    /// Device→host readbacks.
    pub d2h_transfers: u64,
    pub d2h_bytes: u64,
}

impl Device {
    pub fn cpu() -> Result<Device> {
        Self::cpu_with_faults(FaultPlan::from_env())
    }

    /// A CPU device with an explicit fault-injection schedule (tests pass
    /// one directly; `cpu()` reads `DISC_FAULTS`).
    pub fn cpu_with_faults(faults: Option<Arc<FaultPlan>>) -> Result<Device> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Device {
            client,
            temp: TempWorkspace::new()?,
            stats: std::sync::Mutex::new(DeviceStats::default()),
            faults,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The fault schedule this device injects from, if any.
    pub fn faults(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    /// Snapshot of the device's accumulated stats.
    pub fn stats(&self) -> DeviceStats {
        // Stats locks recover from poisoning (`util::relock`): a panicking
        // worker must not take device accounting (and every other worker)
        // down with it.
        crate::util::relock(&self.stats).clone()
    }

    /// Compile HLO text into an executable. The text is round-tripped
    /// through a temp file because the bundled XLA exposes only a file
    /// parser (`HloModuleProto::from_text_file`).
    pub fn compile_hlo_text(&self, text: &str) -> Result<Executable> {
        self.compile_hlo_text_named("kernel", text)
    }

    /// Like [`Device::compile_hlo_text`], with the kernel name embedded in
    /// the temp filename so crash dumps and leftover files are attributable.
    pub fn compile_hlo_text_named(&self, name: &str, text: &str) -> Result<Executable> {
        let path = self.temp.file_for(name);
        std::fs::write(&path, text).context("writing HLO temp file")?;
        let result = self.compile_hlo_file(&path);
        let _ = std::fs::remove_file(&path);
        result
    }

    pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
        faults::check(self.faults.as_deref(), FaultSite::Compile, "compiling HLO")?;
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling HLO: {e}"))?;
        let elapsed = start.elapsed();
        {
            let mut s = crate::util::relock(&self.stats);
            s.compilations += 1;
            s.compile_time += elapsed;
        }
        Ok(Executable { exe, compile_time: elapsed })
    }

    /// Host→device transfer: upload a host tensor as a device-resident
    /// buffer.
    pub fn h2d(&self, t: &Tensor) -> Result<DeviceTensor> {
        faults::check(self.faults.as_deref(), FaultSite::H2d, "h2d transfer")?;
        let lit = tensor_to_literal(t)?;
        let buf = self
            .client
            .buffer_from_host_literal(&lit)
            .map_err(|e| anyhow!("h2d transfer: {e}"))?;
        {
            let mut s = crate::util::relock(&self.stats);
            s.h2d_transfers += 1;
            s.h2d_bytes += t.byte_size() as u64;
        }
        Ok(DeviceTensor { buf, dims: t.dims.clone(), dtype: t.dtype })
    }

    /// Device→host readback of a device-resident tensor.
    pub fn d2h(&self, dt: &DeviceTensor) -> Result<Tensor> {
        faults::check(self.faults.as_deref(), FaultSite::D2h, "d2h readback")?;
        let t = dt.to_host()?;
        {
            let mut s = crate::util::relock(&self.stats);
            s.d2h_transfers += 1;
            s.d2h_bytes += t.byte_size() as u64;
        }
        Ok(t)
    }
}

/// A device-resident tensor: a PJRT buffer plus the host-side metadata the
/// runtime needs to reason about it without a readback.
pub struct DeviceTensor {
    buf: xla::PjRtBuffer,
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl DeviceTensor {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.elems() * self.dtype.byte_size()
    }

    /// Synchronous readback (no stats; prefer [`Device::d2h`] on hot paths
    /// so transfers are accounted).
    pub fn to_host(&self) -> Result<Tensor> {
        let lit = self.buf.to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
        literal_to_tensor(&lit, &self.dims, self.dtype)
    }
}

/// A compiled kernel.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub compile_time: std::time::Duration,
}

impl Executable {
    /// Execute with host tensors; returns the single (non-tuple) output.
    /// `out_dims`/`out_dtype` describe the result buffer (the executor
    /// knows them from codegen).
    pub fn run(&self, inputs: &[&Tensor], out_dims: &[usize], out_dtype: DType) -> Result<Tensor> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("kernel execution: {e}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
        literal_to_tensor(&lit, out_dims, out_dtype)
    }

    /// Execute with device-resident operands; the result stays on device.
    /// This is the launch-plan hot path: no literal marshalling, no
    /// synchronous readback.
    pub fn run_on_device(
        &self,
        inputs: &[&DeviceTensor],
        out_dims: &[usize],
        out_dtype: DType,
    ) -> Result<DeviceTensor> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|d| &d.buf).collect();
        let mut result = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("kernel execution (device): {e}"))?;
        let buf = result
            .get_mut(0)
            .and_then(|r| (!r.is_empty()).then(|| r.remove(0)))
            .ok_or_else(|| anyhow!("device execution produced no output"))?;
        Ok(DeviceTensor { buf, dims: out_dims.to_vec(), dtype: out_dtype })
    }

    /// Execute returning a tuple of outputs (used by multi-output library
    /// entries and AOT model artifacts lowered with `return_tuple=True`).
    pub fn run_tuple(
        &self,
        inputs: &[&Tensor],
        outs: &[(Vec<usize>, DType)],
    ) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("kernel execution: {e}"))?;
        let mut lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
        let parts = lit.decompose_tuple().map_err(|e| anyhow!("decompose: {e}"))?;
        anyhow::ensure!(parts.len() == outs.len(), "tuple arity mismatch");
        parts
            .iter()
            .zip(outs)
            .map(|(l, (dims, dt))| literal_to_tensor(l, dims, *dt))
            .collect()
    }
}

/// Host→device marshalling. Uses the raw-bytes constructor: one copy into
/// the literal, no intermediate rank-1 literal + reshape (hot-path savings
/// measured in EXPERIMENTS.md §Perf).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    fn raw<T>(v: &[T]) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    let lit = match &t.data {
        Data::F32(v) => {
            if t.rank() == 0 {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.dims,
                    raw(v),
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
        }
        Data::I64(v) => {
            if t.rank() == 0 {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S64,
                    &t.dims,
                    raw(v),
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
        }
        Data::I32(v) => {
            if t.rank() == 0 {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &t.dims,
                    raw(v),
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
        }
        Data::Pred(_) => bail!("pred tensors never cross the kernel boundary"),
    };
    Ok(lit)
}

/// Device→host marshalling.
pub fn literal_to_tensor(lit: &xla::Literal, dims: &[usize], dtype: DType) -> Result<Tensor> {
    Ok(match dtype {
        DType::F32 => {
            Tensor::f32(dims, lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?)
        }
        DType::I64 => {
            Tensor::i64(dims, lit.to_vec::<i64>().map_err(|e| anyhow!("to_vec i64: {e}"))?)
        }
        DType::I32 => {
            Tensor::i32(dims, lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?)
        }
        DType::Pred => bail!("pred tensors never cross the kernel boundary"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written HLO text compiles and runs: the codegen contract.
    #[test]
    fn compile_and_run_handwritten_hlo() {
        let hlo = r#"HloModule smoke, entry_computation_layout={(f32[2,3]{1,0}, f32[2,3]{1,0})->f32[2,3]{1,0}}

ENTRY main {
  p0 = f32[2,3]{1,0} parameter(0)
  p1 = f32[2,3]{1,0} parameter(1)
  a = f32[2,3]{1,0} add(p0, p1)
  ROOT t = f32[2,3]{1,0} tanh(a)
}
"#;
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(hlo).unwrap();
        let x = Tensor::f32(&[2, 3], vec![0.0, 0.5, 1.0, -0.5, 2.0, -2.0]);
        let y = Tensor::f32(&[2, 3], vec![0.0; 6]);
        let out = exe.run(&[&x, &y], &[2, 3], DType::F32).unwrap();
        let v = out.as_f32().unwrap();
        for (o, i) in v.iter().zip(x.as_f32().unwrap()) {
            assert!((o - i.tanh()).abs() < 1e-6);
        }
    }

    /// Reduce with region + iota masking — the exact shapes of HLO text the
    /// fused-kernel emitter produces.
    #[test]
    fn compile_and_run_masked_reduce() {
        let hlo = r#"HloModule masked, entry_computation_layout={(f32[2,4]{1,0}, s32[])->f32[2]{0}}

region_add {
  ra = f32[] parameter(0)
  rb = f32[] parameter(1)
  ROOT rr = f32[] add(ra, rb)
}

ENTRY main {
  p0 = f32[2,4]{1,0} parameter(0)
  n = s32[] parameter(1)
  i = s32[2,4]{1,0} iota(), iota_dimension=1
  nb = s32[2,4]{1,0} broadcast(n), dimensions={}
  mask = pred[2,4]{1,0} compare(i, nb), direction=LT
  zero = f32[] constant(0)
  zb = f32[2,4]{1,0} broadcast(zero), dimensions={}
  masked = f32[2,4]{1,0} select(mask, p0, zb)
  init = f32[] constant(0)
  ROOT r = f32[2]{0} reduce(masked, init), dimensions={1}, to_apply=region_add
}
"#;
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(hlo).unwrap();
        // Bucket extent 4, actual 3: the 4th column is garbage and must be
        // masked out of the sum.
        let x = Tensor::f32(&[2, 4], vec![1., 2., 3., 999., 4., 5., 6., 999.]);
        let n = Tensor::i32(&[], vec![3]);
        let out = exe.run(&[&x, &n], &[2], DType::F32).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[6.0, 15.0]);
    }

    #[test]
    fn rejects_garbage_hlo() {
        let dev = Device::cpu().unwrap();
        assert!(dev.compile_hlo_text("not hlo at all").is_err());
    }

    /// Device-resident round trip: run → feed the buffer straight into the
    /// next launch → read back once. Bit-identical to the host path.
    #[test]
    fn device_resident_chain_matches_host_path() {
        let hlo = r#"HloModule neg, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

ENTRY main {
  p0 = f32[4]{0} parameter(0)
  ROOT t = f32[4]{0} tanh(p0)
}
"#;
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(hlo).unwrap();
        let x = Tensor::f32(&[4], vec![0.1, -0.2, 0.3, -0.4]);
        // Host path, twice.
        let h1 = exe.run(&[&x], &[4], DType::F32).unwrap();
        let h2 = exe.run(&[&h1], &[4], DType::F32).unwrap();
        // Device path: one upload, one readback.
        let d0 = dev.h2d(&x).unwrap();
        let d1 = exe.run_on_device(&[&d0], &[4], DType::F32).unwrap();
        let d2 = exe.run_on_device(&[&d1], &[4], DType::F32).unwrap();
        let back = dev.d2h(&d2).unwrap();
        assert_eq!(back, h2, "device-resident chain must be bit-exact");
        let stats = dev.stats();
        assert_eq!(stats.h2d_transfers, 1);
        assert_eq!(stats.d2h_transfers, 1);
    }

    /// Injected faults surface as ordinary `Err`s at the transfer/compile
    /// seams and are counted on the plan, and the device keeps working once
    /// the schedule's limits are exhausted.
    #[test]
    fn injected_device_faults_surface_and_exhaust() {
        let plan = Arc::new(
            FaultPlan::parse("seed=5,compile=1000:1,h2d=1000:1,d2h=1000:1").unwrap(),
        );
        let dev = Device::cpu_with_faults(Some(plan.clone())).unwrap();
        let hlo = r#"HloModule neg, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

ENTRY main {
  p0 = f32[4]{0} parameter(0)
  ROOT t = f32[4]{0} tanh(p0)
}
"#;
        let e = dev.compile_hlo_text(hlo).unwrap_err();
        assert!(format!("{e:#}").contains("injected compile fault"), "{e:#}");
        let exe = dev.compile_hlo_text(hlo).unwrap();
        let x = Tensor::f32(&[4], vec![0.1, -0.2, 0.3, -0.4]);
        let e = dev.h2d(&x).unwrap_err();
        assert!(format!("{e:#}").contains("injected h2d fault"), "{e:#}");
        let d = dev.h2d(&x).unwrap();
        let r = exe.run_on_device(&[&d], &[4], DType::F32).unwrap();
        let e = dev.d2h(&r).unwrap_err();
        assert!(format!("{e:#}").contains("injected d2h fault"), "{e:#}");
        let back = dev.d2h(&r).unwrap();
        assert_eq!(back.as_f32().unwrap().len(), 4);
        assert_eq!(plan.fired(FaultSite::Compile), 1);
        assert_eq!(plan.fired(FaultSite::H2d), 1);
        assert_eq!(plan.fired(FaultSite::D2h), 1);
    }

    /// The temp workspace keeps HLO files in one per-process directory and
    /// removes it when the device is dropped.
    #[test]
    fn temp_workspace_cleans_up_on_drop() {
        let dir = {
            let dev = Device::cpu().unwrap();
            let _ = dev.compile_hlo_text_named(
                "probe",
                "HloModule p, x={}\n\nENTRY main {\n  ROOT c = f32[] constant(1)\n}\n",
            );
            dev.temp.dir.clone()
        };
        assert!(!dir.exists(), "temp dir should be removed on Drop");
    }
}
