//! PJRT device wrapper: compile HLO text, execute with host tensors.
//!
//! This is the "device side" of the reproduction. Fused kernels emitted by
//! `codegen` (HLO text, exactly the interchange format the AOT pipeline
//! uses — see /opt/xla-example/README.md for why text, not serialized
//! protos) are compiled once per (pattern, bucket) and then executed from
//! the hot path with zero Python involvement.

use crate::dhlo::DType;
use crate::runtime::tensor::{Data, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A PJRT device (CPU in this testbed; the same wrapper would target GPU).
pub struct Device {
    client: xla::PjRtClient,
}

/// Compilation + execution statistics a device accumulates (feeds the
/// compile-overhead bench and the CPU-time breakdown).
#[derive(Debug, Default, Clone)]
pub struct DeviceStats {
    pub compilations: u64,
    pub compile_time: std::time::Duration,
    pub executions: u64,
    pub execute_time: std::time::Duration,
}

impl Device {
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Device { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile HLO text into an executable. The text is round-tripped
    /// through a temp file because the bundled XLA exposes only a file
    /// parser (`HloModuleProto::from_text_file`).
    pub fn compile_hlo_text(&self, text: &str) -> Result<Executable> {
        let path = temp_path();
        std::fs::write(&path, text).context("writing HLO temp file")?;
        let result = self.compile_hlo_file(&path);
        let _ = std::fs::remove_file(&path);
        result
    }

    pub fn compile_hlo_file(&self, path: &std::path::Path) -> Result<Executable> {
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling HLO: {e}"))?;
        Ok(Executable { exe, compile_time: start.elapsed() })
    }
}

fn temp_path() -> PathBuf {
    let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("disc_kernel_{}_{n}.hlo.txt", std::process::id()))
}

/// A compiled kernel.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub compile_time: std::time::Duration,
}

impl Executable {
    /// Execute with host tensors; returns the single (non-tuple) output.
    /// `out_dims`/`out_dtype` describe the result buffer (the executor
    /// knows them from codegen).
    pub fn run(&self, inputs: &[&Tensor], out_dims: &[usize], out_dtype: DType) -> Result<Tensor> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("kernel execution: {e}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
        literal_to_tensor(&lit, out_dims, out_dtype)
    }

    /// Execute returning a tuple of outputs (used by multi-output library
    /// entries and AOT model artifacts lowered with `return_tuple=True`).
    pub fn run_tuple(
        &self,
        inputs: &[&Tensor],
        outs: &[(Vec<usize>, DType)],
    ) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("kernel execution: {e}"))?;
        let mut lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("readback: {e}"))?;
        let parts = lit.decompose_tuple().map_err(|e| anyhow!("decompose: {e}"))?;
        anyhow::ensure!(parts.len() == outs.len(), "tuple arity mismatch");
        parts
            .iter()
            .zip(outs)
            .map(|(l, (dims, dt))| literal_to_tensor(l, dims, *dt))
            .collect()
    }
}

/// Host→device marshalling. Uses the raw-bytes constructor: one copy into
/// the literal, no intermediate rank-1 literal + reshape (hot-path savings
/// measured in EXPERIMENTS.md §Perf).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    fn raw<T>(v: &[T]) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    let lit = match &t.data {
        Data::F32(v) => {
            if t.rank() == 0 {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.dims,
                    raw(v),
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
        }
        Data::I64(v) => {
            if t.rank() == 0 {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S64,
                    &t.dims,
                    raw(v),
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
        }
        Data::I32(v) => {
            if t.rank() == 0 {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &t.dims,
                    raw(v),
                )
                .map_err(|e| anyhow!("literal: {e}"))?
            }
        }
        Data::Pred(_) => bail!("pred tensors never cross the kernel boundary"),
    };
    Ok(lit)
}

/// Device→host marshalling.
pub fn literal_to_tensor(lit: &xla::Literal, dims: &[usize], dtype: DType) -> Result<Tensor> {
    Ok(match dtype {
        DType::F32 => {
            Tensor::f32(dims, lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?)
        }
        DType::I64 => {
            Tensor::i64(dims, lit.to_vec::<i64>().map_err(|e| anyhow!("to_vec i64: {e}"))?)
        }
        DType::I32 => {
            Tensor::i32(dims, lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?)
        }
        DType::Pred => bail!("pred tensors never cross the kernel boundary"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written HLO text compiles and runs: the codegen contract.
    #[test]
    fn compile_and_run_handwritten_hlo() {
        let hlo = r#"HloModule smoke, entry_computation_layout={(f32[2,3]{1,0}, f32[2,3]{1,0})->f32[2,3]{1,0}}

ENTRY main {
  p0 = f32[2,3]{1,0} parameter(0)
  p1 = f32[2,3]{1,0} parameter(1)
  a = f32[2,3]{1,0} add(p0, p1)
  ROOT t = f32[2,3]{1,0} tanh(a)
}
"#;
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(hlo).unwrap();
        let x = Tensor::f32(&[2, 3], vec![0.0, 0.5, 1.0, -0.5, 2.0, -2.0]);
        let y = Tensor::f32(&[2, 3], vec![0.0; 6]);
        let out = exe.run(&[&x, &y], &[2, 3], DType::F32).unwrap();
        let v = out.as_f32().unwrap();
        for (o, i) in v.iter().zip(x.as_f32().unwrap()) {
            assert!((o - i.tanh()).abs() < 1e-6);
        }
    }

    /// Reduce with region + iota masking — the exact shapes of HLO text the
    /// fused-kernel emitter produces.
    #[test]
    fn compile_and_run_masked_reduce() {
        let hlo = r#"HloModule masked, entry_computation_layout={(f32[2,4]{1,0}, s32[])->f32[2]{0}}

region_add {
  ra = f32[] parameter(0)
  rb = f32[] parameter(1)
  ROOT rr = f32[] add(ra, rb)
}

ENTRY main {
  p0 = f32[2,4]{1,0} parameter(0)
  n = s32[] parameter(1)
  i = s32[2,4]{1,0} iota(), iota_dimension=1
  nb = s32[2,4]{1,0} broadcast(n), dimensions={}
  mask = pred[2,4]{1,0} compare(i, nb), direction=LT
  zero = f32[] constant(0)
  zb = f32[2,4]{1,0} broadcast(zero), dimensions={}
  masked = f32[2,4]{1,0} select(mask, p0, zb)
  init = f32[] constant(0)
  ROOT r = f32[2]{0} reduce(masked, init), dimensions={1}, to_apply=region_add
}
"#;
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(hlo).unwrap();
        // Bucket extent 4, actual 3: the 4th column is garbage and must be
        // masked out of the sum.
        let x = Tensor::f32(&[2, 4], vec![1., 2., 3., 999., 4., 5., 6., 999.]);
        let n = Tensor::i32(&[], vec![3]);
        let out = exe.run(&[&x, &n], &[2], DType::F32).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[6.0, 15.0]);
    }

    #[test]
    fn rejects_garbage_hlo() {
        let dev = Device::cpu().unwrap();
        assert!(dev.compile_hlo_text("not hlo at all").is_err());
    }
}
