//! Dynamic buffer management (§4.2.2).
//!
//! Two layers, as in the paper:
//!
//! 1. **Compile-time liveness**: the program generator places `Dealloc`
//!    steps immediately after a value's last use (free-as-soon-as-dead) and
//!    computes reuse classes from the tensor-size-equality constraint
//!    (buffers provably the same size can share an arena slot — see
//!    `runtime/memplan.rs` for the symbolic planner built on top).
//! 2. **Runtime cached allocator**: freed blocks go to size-bucketed free
//!    lists (the paper lowers `alloc`/`dealloc` to TF/PyTorch's cached
//!    allocator; ours is built from scratch). Allocation requests are
//!    served from the pool when possible, avoiding the underlying
//!    allocator on the hot path.
//!
//! Device-side accounting lives in [`DeviceArena`]: one fault-armed
//! `acquire(class, bytes)` entry point returning an RAII [`ArenaLease`],
//! shared by solo replay, batch replay, KV slabs, and plan reservations.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex, MutexGuard};

/// Size-bucketed pool of f32 blocks (the dominant tensor dtype on the
/// device path; other dtypes fall through to the system allocator and are
/// still counted).
#[derive(Debug, Default)]
pub struct BufferPool {
    free: HashMap<usize, Vec<Vec<f32>>>,
    pub stats: PoolStats,
    /// Maximum blocks parked per bucket (bounds idle memory).
    pub max_per_bucket: usize,
    /// Device-side arena accounting for the launch-plan pipeline.
    pub device: DeviceArena,
}

/// Lifetime class of a device allocation. Every class shares the single
/// fault-armed [`DeviceArena::acquire`] path but is accounted separately,
/// because the classes have different lifetimes and different consumers:
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResidencyClass {
    /// Solo-replay intermediates (die at a `Dealloc` within one launch
    /// plan's walk, or at the planned extent's release).
    Plan,
    /// Batch-replay intermediates (same lifetime shape, group granularity).
    Batch,
    /// KV-cache slabs: live across every launch of a decode request, die
    /// at request exit or bucket rollover. Never parked — rollover sizes
    /// differ by construction.
    Kv,
    /// Plan-install reservations: capacity promised to an installed
    /// launch/batch plan, held for the plan's whole cache lifetime and
    /// released when the plan drops (FIFO eviction shrinks the
    /// reservation — the lease makes that automatic).
    Reserve,
}

/// Per-class accounting inside the arena.
///
/// `resident`/`high_water` track *live* bytes. The parked free list models
/// what a real cached allocator holds on to: a released block stays part
/// of the process's device footprint until an acquire of the *same size*
/// reuses it (or `trim` drops it). `footprint_high_water` is therefore the
/// honest peak-memory figure: max over time of live + parked bytes.
#[derive(Debug, Default)]
struct ClassState {
    resident: u64,
    high_water: u64,
    /// Exact-byte-size free list: size -> parked block count.
    parked: BTreeMap<u64, usize>,
    parked_bytes: u64,
    footprint_high_water: u64,
    /// Outstanding leases (leak check: must reconcile to zero at quiesce).
    leases: usize,
}

impl ClassState {
    fn footprint(&self) -> u64 {
        self.resident + self.parked_bytes
    }

    fn acquire(&mut self, bytes: u64, park: bool) {
        if park {
            // Consume an exact-size parked block if one exists: the bytes
            // move from parked back to live, footprint unchanged.
            if let Some(n) = self.parked.get_mut(&bytes) {
                *n -= 1;
                if *n == 0 {
                    self.parked.remove(&bytes);
                }
                self.parked_bytes -= bytes;
            }
        }
        self.resident += bytes;
        self.high_water = self.high_water.max(self.resident);
        self.footprint_high_water = self.footprint_high_water.max(self.footprint());
        self.leases += 1;
    }

    fn release(&mut self, bytes: u64, park: bool) {
        self.resident = self.resident.saturating_sub(bytes);
        if park {
            *self.parked.entry(bytes).or_insert(0) += 1;
            self.parked_bytes += bytes;
            self.footprint_high_water = self.footprint_high_water.max(self.footprint());
        }
        self.leases = self.leases.saturating_sub(1);
    }

    fn trim(&mut self) {
        self.parked.clear();
        self.parked_bytes = 0;
    }
}

#[derive(Debug, Default)]
struct ArenaInner {
    plan: ClassState,
    batch: ClassState,
    kv: ClassState,
    /// Outstanding plan reservations as a size multiset: the arena's
    /// reserved capacity is the *max* outstanding reservation, and a
    /// reservation disappears when its lease drops — so FIFO plan
    /// eviction shrinks the figure instead of ratcheting it up forever.
    reserve: BTreeMap<u64, usize>,
    reserve_leases: usize,
}

impl ArenaInner {
    fn class(&mut self, c: ResidencyClass) -> &mut ClassState {
        match c {
            ResidencyClass::Plan => &mut self.plan,
            ResidencyClass::Batch => &mut self.batch,
            ResidencyClass::Kv => &mut self.kv,
            ResidencyClass::Reserve => unreachable!("Reserve uses the size multiset"),
        }
    }
}

/// Accounting for device-resident buffers held between kernel launches.
///
/// One entry point: [`acquire`] takes a [`ResidencyClass`] and a byte
/// count, runs the `FaultSite::DeviceOom` seam *before* accounting (a
/// failed acquire leaves the arena untouched), and returns an RAII
/// [`ArenaLease`] that releases its bytes on drop — no caller ever
/// balances a manual release, so demotion/unwind paths cannot leak.
///
/// `Plan`/`Batch` releases *park* their block on an exact-size free list
/// (modeling a cached device allocator: the footprint stays until an
/// equal-size acquire reuses it), so `footprint_high_water` reports what a
/// real allocator would peak at — the figure the symbolic memory planner
/// is gated on shrinking. `Kv` releases return bytes outright (slab sizes
/// differ across rollovers; parking them would never hit). `Reserve`
/// leases track installed-plan capacity promises as a max-of-multiset.
///
/// Persistently resident GEMM weights remain a separate lifetime class
/// accounted by the library (`GemmLibrary::weight_resident_bytes`); a
/// deployment sizes device memory as arena reservation + weight residency.
///
/// [`acquire`]: DeviceArena::acquire
#[derive(Debug, Default)]
pub struct DeviceArena {
    inner: Arc<Mutex<ArenaInner>>,
}

/// RAII guard for one arena allocation: releases its bytes back to the
/// arena (parking them for `Plan`/`Batch`) when dropped. Cloned-arena
/// ownership keeps the lease valid wherever it travels (plans in the
/// executor cache, coordinator decode members, replay device slots).
#[derive(Debug)]
pub struct ArenaLease {
    inner: Arc<Mutex<ArenaInner>>,
    class: ResidencyClass,
    bytes: u64,
}

impl ArenaLease {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn class(&self) -> ResidencyClass {
        self.class
    }
}

impl Drop for ArenaLease {
    fn drop(&mut self) {
        let mut g = lock(&self.inner);
        match self.class {
            ResidencyClass::Plan | ResidencyClass::Batch => {
                g.class(self.class).release(self.bytes, true)
            }
            ResidencyClass::Kv => g.kv.release(self.bytes, false),
            ResidencyClass::Reserve => {
                if let Some(n) = g.reserve.get_mut(&self.bytes) {
                    *n -= 1;
                    if *n == 0 {
                        g.reserve.remove(&self.bytes);
                    }
                }
                g.reserve_leases = g.reserve_leases.saturating_sub(1);
            }
        }
    }
}

/// Leases drop during panic unwinds (worker supervision); recover the
/// guard rather than wedging every sibling holding a lease on the same
/// arena.
fn lock(inner: &Mutex<ArenaInner>) -> MutexGuard<'_, ArenaInner> {
    inner.lock().unwrap_or_else(|p| p.into_inner())
}

impl DeviceArena {
    /// Acquire `bytes` in residency class `class`.
    ///
    /// The `FaultSite::DeviceOom` seam fires *before* accounting for the
    /// `Plan`/`Batch`/`Kv` classes, so a failed acquire holds no phantom
    /// residency and the caller demotes down the execution ladder.
    /// `Reserve` is deliberately un-armed: reservations are taken on the
    /// record path, which must stay fault-silent so chaos schedules hit
    /// replays deterministically — callers pass `None`.
    pub fn acquire(
        &self,
        class: ResidencyClass,
        bytes: u64,
        faults: Option<&crate::runtime::faults::FaultPlan>,
    ) -> anyhow::Result<ArenaLease> {
        let context = match class {
            ResidencyClass::Plan | ResidencyClass::Batch => "device arena acquire",
            ResidencyClass::Kv => "kv slab acquire",
            ResidencyClass::Reserve => "plan reservation",
        };
        if !matches!(class, ResidencyClass::Reserve) {
            crate::runtime::faults::check(
                faults,
                crate::runtime::faults::FaultSite::DeviceOom,
                context,
            )?;
        }
        let mut g = lock(&self.inner);
        match class {
            ResidencyClass::Plan | ResidencyClass::Batch => g.class(class).acquire(bytes, true),
            ResidencyClass::Kv => g.kv.acquire(bytes, false),
            ResidencyClass::Reserve => {
                *g.reserve.entry(bytes).or_insert(0) += 1;
                g.reserve_leases += 1;
            }
        }
        drop(g);
        Ok(ArenaLease {
            inner: Arc::clone(&self.inner),
            class,
            bytes,
        })
    }

    /// Drop the parked free-list blocks of one class (footprint shrinks to
    /// live bytes; the high-water mark is monotone and keeps its peak).
    pub fn trim(&self, class: ResidencyClass) {
        let mut g = lock(&self.inner);
        match class {
            ResidencyClass::Reserve => {}
            _ => g.class(class).trim(),
        }
    }

    /// Live `Plan` + `Batch` intermediate bytes.
    pub fn resident_bytes(&self) -> u64 {
        let mut g = lock(&self.inner);
        g.plan.resident + g.batch.resident
    }

    /// Peak live intermediate bytes (`Plan` + `Batch` high waters summed).
    pub fn high_water_bytes(&self) -> u64 {
        let mut g = lock(&self.inner);
        g.plan.high_water + g.batch.high_water
    }

    /// Peak footprint (live + parked) of one class — what a cached device
    /// allocator would have held at its worst moment.
    pub fn footprint_high_water(&self, class: ResidencyClass) -> u64 {
        let mut g = lock(&self.inner);
        match class {
            ResidencyClass::Reserve => 0,
            _ => g.class(class).footprint_high_water,
        }
    }

    /// Currently live KV slab bytes.
    pub fn kv_resident_bytes(&self) -> u64 {
        lock(&self.inner).kv.resident
    }

    /// Peak KV slab residency observed.
    pub fn kv_high_water_bytes(&self) -> u64 {
        lock(&self.inner).kv.high_water
    }

    /// Reserved capacity: the *max* outstanding plan reservation (zero
    /// once every holding plan has dropped).
    pub fn reserved_bytes(&self) -> u64 {
        lock(&self.inner).reserve.keys().next_back().copied().unwrap_or(0)
    }

    /// Outstanding lease count for `class` — the leak check every serving
    /// harness reconciles to zero at quiesce.
    pub fn outstanding(&self, class: ResidencyClass) -> usize {
        let mut g = lock(&self.inner);
        match class {
            ResidencyClass::Reserve => g.reserve_leases,
            _ => g.class(class).leases,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub allocs: u64,
    pub pool_hits: u64,
    pub system_allocs: u64,
    pub frees: u64,
    pub bytes_allocated: u64,
    pub high_water_bytes: u64,
    cur_bytes: u64,
}

impl BufferPool {
    pub fn new() -> Self {
        BufferPool {
            free: HashMap::new(),
            stats: PoolStats::default(),
            max_per_bucket: 16,
            device: DeviceArena::default(),
        }
    }

    fn bucket(n: usize) -> usize {
        crate::util::next_pow2(n.max(1))
    }

    /// Get an f32 block of exactly `n` elements (capacity may be larger).
    pub fn alloc_f32(&mut self, n: usize, fill: f32) -> Vec<f32> {
        self.stats.allocs += 1;
        self.stats.bytes_allocated += (n * 4) as u64;
        self.stats.cur_bytes += (n * 4) as u64;
        self.stats.high_water_bytes = self.stats.high_water_bytes.max(self.stats.cur_bytes);
        let b = Self::bucket(n);
        if let Some(list) = self.free.get_mut(&b) {
            if let Some(mut v) = list.pop() {
                self.stats.pool_hits += 1;
                v.clear();
                v.resize(n, fill);
                return v;
            }
        }
        self.stats.system_allocs += 1;
        let mut v = Vec::with_capacity(b);
        v.resize(n, fill);
        v
    }

    /// Return a block to the pool.
    pub fn free_f32(&mut self, v: Vec<f32>) {
        self.stats.frees += 1;
        self.stats.cur_bytes = self.stats.cur_bytes.saturating_sub((v.len() * 4) as u64);
        let b = Self::bucket(v.capacity().max(1));
        let list = self.free.entry(b).or_default();
        if list.len() < self.max_per_bucket {
            list.push(v);
        }
    }

    /// Reuse ratio so far.
    pub fn hit_rate(&self) -> f64 {
        if self.stats.allocs == 0 {
            0.0
        } else {
            self.stats.pool_hits as f64 / self.stats.allocs as f64
        }
    }
}

/// Compile-time liveness: for each value, the index of the last step that
/// reads it. The program generator turns this into `Dealloc` placements.
pub fn last_use_steps(uses_per_step: &[Vec<usize>], n_values: usize) -> Vec<Option<usize>> {
    let mut last = vec![None; n_values];
    for (step, uses) in uses_per_step.iter().enumerate() {
        for &v in uses {
            last[v] = Some(step);
        }
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_blocks() {
        let mut p = BufferPool::new();
        let a = p.alloc_f32(100, 0.0);
        assert_eq!(p.stats.system_allocs, 1);
        p.free_f32(a);
        let b = p.alloc_f32(90, 1.0); // same bucket (128)
        assert_eq!(p.stats.pool_hits, 1);
        assert_eq!(p.stats.system_allocs, 1);
        assert_eq!(b.len(), 90);
        assert!(b.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn distinct_buckets_do_not_alias() {
        let mut p = BufferPool::new();
        let a = p.alloc_f32(10, 0.0);
        p.free_f32(a);
        let _b = p.alloc_f32(1000, 0.0); // different bucket: fresh alloc
        assert_eq!(p.stats.system_allocs, 2);
    }

    #[test]
    fn high_water_tracking() {
        let mut p = BufferPool::new();
        let a = p.alloc_f32(256, 0.0);
        let b = p.alloc_f32(256, 0.0);
        assert_eq!(p.stats.high_water_bytes, 2 * 256 * 4);
        p.free_f32(a);
        p.free_f32(b);
        let _ = p.alloc_f32(256, 0.0);
        assert_eq!(p.stats.high_water_bytes, 2 * 256 * 4, "reuse keeps high water flat");
    }

    #[test]
    fn pool_bounds_parked_blocks() {
        let mut p = BufferPool::new();
        p.max_per_bucket = 2;
        let blocks: Vec<_> = (0..4).map(|_| p.alloc_f32(64, 0.0)).collect();
        for b in blocks {
            p.free_f32(b);
        }
        assert_eq!(p.free.get(&64).map(|l| l.len()), Some(2));
    }

    #[test]
    fn checked_acquire_injects_oom_without_phantom_residency() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        let plan = FaultPlan::parse("seed=1,oom=1000:1").unwrap();
        let a = DeviceArena::default();
        let e = a
            .acquire(ResidencyClass::Plan, 128, Some(&plan))
            .unwrap_err();
        assert!(format!("{e:#}").contains("injected oom fault"), "{e:#}");
        assert_eq!(a.resident_bytes(), 0, "failed acquire must not account bytes");
        assert_eq!(a.outstanding(ResidencyClass::Plan), 0);
        let lease = a.acquire(ResidencyClass::Plan, 128, Some(&plan)).unwrap();
        assert_eq!(a.resident_bytes(), 128);
        assert_eq!(lease.bytes(), 128);
        assert_eq!(plan.fired(FaultSite::DeviceOom), 1);
        let b = DeviceArena::default();
        let _l = b.acquire(ResidencyClass::Plan, 64, None).unwrap();
        assert_eq!(b.resident_bytes(), 64);
    }

    #[test]
    fn kv_slabs_account_separately_and_inject_oom() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        let a = DeviceArena::default();
        let _inter = a.acquire(ResidencyClass::Plan, 100, None).unwrap();
        let slab = a.acquire(ResidencyClass::Kv, 4096, None).unwrap();
        assert_eq!(a.resident_bytes(), 100, "slabs must not count as intermediates");
        assert_eq!(a.kv_resident_bytes(), 4096);
        assert_eq!(a.kv_high_water_bytes(), 4096);
        // Rollover: drop the old slab's lease, acquire the doubled one.
        drop(slab);
        let slab = a.acquire(ResidencyClass::Kv, 8192, None).unwrap();
        assert_eq!(a.kv_resident_bytes(), 8192);
        assert_eq!(a.kv_high_water_bytes(), 8192);
        drop(slab);
        assert_eq!(a.kv_resident_bytes(), 0, "request exit must release its slab");
        assert_eq!(a.outstanding(ResidencyClass::Kv), 0);
        // The OOM seam fires before accounting, like the Plan class.
        let plan = FaultPlan::parse("seed=1,oom=1000:1").unwrap();
        let e = a.acquire(ResidencyClass::Kv, 64, Some(&plan)).unwrap_err();
        assert!(format!("{e:#}").contains("injected oom fault"), "{e:#}");
        assert_eq!(a.kv_resident_bytes(), 0, "failed slab acquire must not account bytes");
        assert_eq!(plan.fired(FaultSite::DeviceOom), 1);
    }

    #[test]
    fn released_blocks_park_and_exact_size_reuse_keeps_footprint_flat() {
        let a = DeviceArena::default();
        let l = a.acquire(ResidencyClass::Plan, 1000, None).unwrap();
        drop(l); // parks: footprint stays at 1000
        assert_eq!(a.resident_bytes(), 0);
        assert_eq!(a.footprint_high_water(ResidencyClass::Plan), 1000);
        // Exact-size reacquire consumes the parked block: no footprint growth.
        let l = a.acquire(ResidencyClass::Plan, 1000, None).unwrap();
        assert_eq!(a.footprint_high_water(ResidencyClass::Plan), 1000);
        drop(l);
        // A different size cannot reuse the parked block: footprint grows.
        let l = a.acquire(ResidencyClass::Plan, 600, None).unwrap();
        assert_eq!(a.footprint_high_water(ResidencyClass::Plan), 1600);
        drop(l);
        a.trim(ResidencyClass::Plan);
        let l = a.acquire(ResidencyClass::Plan, 600, None).unwrap();
        assert_eq!(
            a.footprint_high_water(ResidencyClass::Plan),
            1600,
            "high water is monotone across a trim"
        );
        assert_eq!(a.resident_bytes(), 600);
        drop(l);
    }

    #[test]
    fn reservation_shrinks_when_its_plan_drops() {
        // Regression: the old `reserve()` only ever maxed `reserved_bytes`,
        // so FIFO plan eviction never returned capacity. Reservations are
        // leases now: eviction drops the lease and the figure shrinks to
        // the largest reservation still outstanding.
        let a = DeviceArena::default();
        let big = a.acquire(ResidencyClass::Reserve, 4096, None).unwrap();
        let small = a.acquire(ResidencyClass::Reserve, 1024, None).unwrap();
        assert_eq!(a.reserved_bytes(), 4096);
        assert_eq!(a.outstanding(ResidencyClass::Reserve), 2);
        drop(big); // FIFO evicts the big plan
        assert_eq!(a.reserved_bytes(), 1024, "eviction must shrink the reservation");
        drop(small);
        assert_eq!(a.reserved_bytes(), 0);
        assert_eq!(a.outstanding(ResidencyClass::Reserve), 0);
    }

    #[test]
    fn batch_class_accounts_separately_from_plan() {
        let a = DeviceArena::default();
        let p = a.acquire(ResidencyClass::Plan, 300, None).unwrap();
        let b = a.acquire(ResidencyClass::Batch, 500, None).unwrap();
        assert_eq!(a.resident_bytes(), 800);
        assert_eq!(a.footprint_high_water(ResidencyClass::Plan), 300);
        assert_eq!(a.footprint_high_water(ResidencyClass::Batch), 500);
        drop(p);
        drop(b);
        assert_eq!(a.resident_bytes(), 0);
        assert_eq!(a.outstanding(ResidencyClass::Plan), 0);
        assert_eq!(a.outstanding(ResidencyClass::Batch), 0);
    }

    #[test]
    fn liveness_last_use() {
        // steps read: [0], [0,1], [2]
        let uses = vec![vec![0], vec![0, 1], vec![2]];
        let last = last_use_steps(&uses, 4);
        assert_eq!(last, vec![Some(1), Some(1), Some(2), None]);
    }
}
