//! Dynamic buffer management (§4.2.2).
//!
//! Two layers, as in the paper:
//!
//! 1. **Compile-time liveness**: the program generator places `Dealloc`
//!    steps immediately after a value's last use (free-as-soon-as-dead) and
//!    computes reuse classes from the tensor-size-equality constraint
//!    (buffers provably the same size can share an arena slot).
//! 2. **Runtime cached allocator**: freed blocks go to size-bucketed free
//!    lists (the paper lowers `alloc`/`dealloc` to TF/PyTorch's cached
//!    allocator; ours is built from scratch). Allocation requests are
//!    served from the pool when possible, avoiding the underlying
//!    allocator on the hot path.

use std::collections::HashMap;

/// Size-bucketed pool of f32 blocks (the dominant tensor dtype on the
/// device path; other dtypes fall through to the system allocator and are
/// still counted).
#[derive(Debug, Default)]
pub struct BufferPool {
    free: HashMap<usize, Vec<Vec<f32>>>,
    pub stats: PoolStats,
    /// Maximum blocks parked per bucket (bounds idle memory).
    pub max_per_bucket: usize,
    /// Device-side arena accounting for the launch-plan pipeline.
    pub device: DeviceArena,
}

/// Accounting for device-resident buffers held between kernel launches.
///
/// Capacity is *reserved* up front from each installed launch plan's
/// liveness (the peak over its `Dealloc`-delimited live set — computed at
/// plan-record time from the compile-time dealloc placement), so a serving
/// process knows its device footprint before the stream arrives; the
/// resident counters then track what the replayed flows actually hold.
///
/// The arena covers *intermediates* (values that die at a `Dealloc`).
/// Persistently resident GEMM weights are a different lifetime class —
/// they outlive every plan that pins them — and are accounted separately
/// by the library (`GemmLibrary::weight_resident_bytes`, surfaced as
/// `RunMetrics::weight_resident_bytes`); a deployment sizes device memory
/// as arena reservation + weight residency.
#[derive(Debug, Default)]
pub struct DeviceArena {
    /// Capacity reserved from installed plans (max over plans).
    pub reserved_bytes: u64,
    /// Currently live device-resident bytes.
    pub resident_bytes: u64,
    /// Peak residency observed.
    pub high_water_bytes: u64,
    /// Currently live KV-cache slab bytes (decode requests). A third
    /// lifetime class next to intermediates and weights: slabs outlive
    /// every launch of their request but die when the request exits.
    pub kv_resident_bytes: u64,
    /// Peak KV slab residency observed.
    pub kv_high_water_bytes: u64,
}

impl DeviceArena {
    /// Reserve capacity for a newly installed plan.
    pub fn reserve(&mut self, plan_peak_bytes: u64) {
        self.reserved_bytes = self.reserved_bytes.max(plan_peak_bytes);
    }

    /// A device buffer of `bytes` became live.
    pub fn acquire(&mut self, bytes: u64) {
        self.resident_bytes += bytes;
        self.high_water_bytes = self.high_water_bytes.max(self.resident_bytes);
    }

    /// Fallible acquire: the seam where device allocation can fail. With a
    /// fault plan armed this simulates an OOM (`FaultSite::DeviceOom`)
    /// *before* accounting the bytes, so a failed acquire leaves the arena
    /// untouched and the replay tiers demote down the execution ladder
    /// instead of holding phantom residency.
    pub fn acquire_checked(
        &mut self,
        bytes: u64,
        faults: Option<&crate::runtime::faults::FaultPlan>,
    ) -> anyhow::Result<()> {
        crate::runtime::faults::check(
            faults,
            crate::runtime::faults::FaultSite::DeviceOom,
            "device arena acquire",
        )?;
        self.acquire(bytes);
        Ok(())
    }

    /// A device buffer of `bytes` was released.
    pub fn release(&mut self, bytes: u64) {
        self.resident_bytes = self.resident_bytes.saturating_sub(bytes);
    }

    /// Fallible KV-slab acquire: same OOM seam as [`acquire_checked`]
    /// (`FaultSite::DeviceOom` fires *before* accounting), but the bytes
    /// land in the KV residency class — slabs live across launches for a
    /// whole decode request, so mixing them into `resident_bytes` would
    /// poison the per-plan intermediate accounting that replay snapshots
    /// and restores on demotion.
    ///
    /// [`acquire_checked`]: DeviceArena::acquire_checked
    pub fn kv_acquire_checked(
        &mut self,
        bytes: u64,
        faults: Option<&crate::runtime::faults::FaultPlan>,
    ) -> anyhow::Result<()> {
        crate::runtime::faults::check(
            faults,
            crate::runtime::faults::FaultSite::DeviceOom,
            "kv slab acquire",
        )?;
        self.kv_resident_bytes += bytes;
        self.kv_high_water_bytes = self.kv_high_water_bytes.max(self.kv_resident_bytes);
        Ok(())
    }

    /// A KV slab of `bytes` was released (request exit or bucket rollover).
    pub fn kv_release(&mut self, bytes: u64) {
        self.kv_resident_bytes = self.kv_resident_bytes.saturating_sub(bytes);
    }
}

#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub allocs: u64,
    pub pool_hits: u64,
    pub system_allocs: u64,
    pub frees: u64,
    pub bytes_allocated: u64,
    pub high_water_bytes: u64,
    cur_bytes: u64,
}

impl BufferPool {
    pub fn new() -> Self {
        BufferPool {
            free: HashMap::new(),
            stats: PoolStats::default(),
            max_per_bucket: 16,
            device: DeviceArena::default(),
        }
    }

    fn bucket(n: usize) -> usize {
        crate::util::next_pow2(n.max(1))
    }

    /// Get an f32 block of exactly `n` elements (capacity may be larger).
    pub fn alloc_f32(&mut self, n: usize, fill: f32) -> Vec<f32> {
        self.stats.allocs += 1;
        self.stats.bytes_allocated += (n * 4) as u64;
        self.stats.cur_bytes += (n * 4) as u64;
        self.stats.high_water_bytes = self.stats.high_water_bytes.max(self.stats.cur_bytes);
        let b = Self::bucket(n);
        if let Some(list) = self.free.get_mut(&b) {
            if let Some(mut v) = list.pop() {
                self.stats.pool_hits += 1;
                v.clear();
                v.resize(n, fill);
                return v;
            }
        }
        self.stats.system_allocs += 1;
        let mut v = Vec::with_capacity(b);
        v.resize(n, fill);
        v
    }

    /// Return a block to the pool.
    pub fn free_f32(&mut self, v: Vec<f32>) {
        self.stats.frees += 1;
        self.stats.cur_bytes = self.stats.cur_bytes.saturating_sub((v.len() * 4) as u64);
        let b = Self::bucket(v.capacity().max(1));
        let list = self.free.entry(b).or_default();
        if list.len() < self.max_per_bucket {
            list.push(v);
        }
    }

    /// Reuse ratio so far.
    pub fn hit_rate(&self) -> f64 {
        if self.stats.allocs == 0 {
            0.0
        } else {
            self.stats.pool_hits as f64 / self.stats.allocs as f64
        }
    }
}

/// Compile-time liveness: for each value, the index of the last step that
/// reads it. The program generator turns this into `Dealloc` placements.
pub fn last_use_steps(uses_per_step: &[Vec<usize>], n_values: usize) -> Vec<Option<usize>> {
    let mut last = vec![None; n_values];
    for (step, uses) in uses_per_step.iter().enumerate() {
        for &v in uses {
            last[v] = Some(step);
        }
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_blocks() {
        let mut p = BufferPool::new();
        let a = p.alloc_f32(100, 0.0);
        assert_eq!(p.stats.system_allocs, 1);
        p.free_f32(a);
        let b = p.alloc_f32(90, 1.0); // same bucket (128)
        assert_eq!(p.stats.pool_hits, 1);
        assert_eq!(p.stats.system_allocs, 1);
        assert_eq!(b.len(), 90);
        assert!(b.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn distinct_buckets_do_not_alias() {
        let mut p = BufferPool::new();
        let a = p.alloc_f32(10, 0.0);
        p.free_f32(a);
        let _b = p.alloc_f32(1000, 0.0); // different bucket: fresh alloc
        assert_eq!(p.stats.system_allocs, 2);
    }

    #[test]
    fn high_water_tracking() {
        let mut p = BufferPool::new();
        let a = p.alloc_f32(256, 0.0);
        let b = p.alloc_f32(256, 0.0);
        assert_eq!(p.stats.high_water_bytes, 2 * 256 * 4);
        p.free_f32(a);
        p.free_f32(b);
        let _ = p.alloc_f32(256, 0.0);
        assert_eq!(p.stats.high_water_bytes, 2 * 256 * 4, "reuse keeps high water flat");
    }

    #[test]
    fn pool_bounds_parked_blocks() {
        let mut p = BufferPool::new();
        p.max_per_bucket = 2;
        let blocks: Vec<_> = (0..4).map(|_| p.alloc_f32(64, 0.0)).collect();
        for b in blocks {
            p.free_f32(b);
        }
        assert_eq!(p.free.get(&64).map(|l| l.len()), Some(2));
    }

    #[test]
    fn checked_acquire_injects_oom_without_phantom_residency() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        let plan = FaultPlan::parse("seed=1,oom=1000:1").unwrap();
        let mut a = DeviceArena::default();
        let e = a.acquire_checked(128, Some(&plan)).unwrap_err();
        assert!(format!("{e:#}").contains("injected oom fault"), "{e:#}");
        assert_eq!(a.resident_bytes, 0, "failed acquire must not account bytes");
        a.acquire_checked(128, Some(&plan)).unwrap();
        assert_eq!(a.resident_bytes, 128);
        assert_eq!(plan.fired(FaultSite::DeviceOom), 1);
        let mut b = DeviceArena::default();
        b.acquire_checked(64, None).unwrap();
        assert_eq!(b.resident_bytes, 64);
    }

    #[test]
    fn kv_slabs_account_separately_and_inject_oom() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        let mut a = DeviceArena::default();
        a.acquire(100);
        a.kv_acquire_checked(4096, None).unwrap();
        assert_eq!(a.resident_bytes, 100, "slabs must not count as intermediates");
        assert_eq!(a.kv_resident_bytes, 4096);
        assert_eq!(a.kv_high_water_bytes, 4096);
        // Rollover: release the old slab, acquire the doubled one.
        a.kv_release(4096);
        a.kv_acquire_checked(8192, None).unwrap();
        assert_eq!(a.kv_resident_bytes, 8192);
        assert_eq!(a.kv_high_water_bytes, 8192);
        a.kv_release(8192);
        assert_eq!(a.kv_resident_bytes, 0, "request exit must release its slab");
        // The OOM seam fires before accounting, like acquire_checked.
        let plan = FaultPlan::parse("seed=1,oom=1000:1").unwrap();
        let e = a.kv_acquire_checked(64, Some(&plan)).unwrap_err();
        assert!(format!("{e:#}").contains("injected oom fault"), "{e:#}");
        assert_eq!(a.kv_resident_bytes, 0, "failed slab acquire must not account bytes");
        assert_eq!(plan.fired(FaultSite::DeviceOom), 1);
    }

    #[test]
    fn liveness_last_use() {
        // steps read: [0], [0,1], [2]
        let uses = vec![vec![0], vec![0, 1], vec![2]];
        let last = last_use_steps(&uses, 4);
        assert_eq!(last, vec![Some(1), Some(1), Some(2), None]);
    }
}
