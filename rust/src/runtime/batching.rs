//! Cross-request batching: execute several queued requests as ONE walk of
//! the generated flow, stacked along a shared leading dynamic symbol.
//!
//! Bucketed kernels make the leading dimension cheap: a kernel compiled
//! for bucket extents serves any actual extent inside the bucket, so three
//! queued requests of 2 rows each can ride one launch at 6 rows — landing
//! in the same bucket family (often the very same kernel) that solo
//! requests already populated. The serving coordinator groups queued
//! requests whose *residual* symbol bindings (everything except the
//! leading batch symbol) agree and hands them to
//! [`Executor::run_batch`](crate::runtime::executor::Executor), which
//! concatenates their inputs along the leading axis, executes the step
//! sequence once, and slices per-request outputs back out.
//!
//! Batching must stay **bit-exact** against the single-request
//! interpreter, and most interesting programs (transformer, BERT) are not
//! uniformly row-parallel: attention mixes rows across the dynamic axis,
//! so naively concatenating sequences would attend across requests. The
//! static [`analyze`] pass therefore classifies every step of the
//! generated flow:
//!
//! * [`BatchMode::Stacked`] — the step maps rows of the leading symbol
//!   independently (elementwise chains, row-wise reduces such as
//!   softmax/layernorm over trailing axes, `[rows, k] · [k, n]` GEMMs,
//!   embedding gathers). Executed once over the concatenated values; row
//!   `r` of the stacked result is bitwise the row the owning request
//!   would have computed alone, because bucketed kernels compute each
//!   row from that row's lanes only (trailing-axis masking is shared —
//!   the residual bindings agree by construction).
//! * [`BatchMode::Shared`] — derived from constants only; executed once
//!   and shared by every member.
//! * [`BatchMode::PerRequest`] — anything that couples rows across the
//!   leading axis (attention scores/softmax over the dynamic axis,
//!   axis-0 transposes/slices, extent reads). Executed once per member
//!   request, exactly as solo execution would.
//!
//! Values cross between the groups by slicing (stacked → per-request
//! rows) and concatenation (per-request → stacked), both contiguous
//! row-range copies accounted in `RunMetrics::batch_stack_bytes`.
//!
//! Programs with data-dependent extents (`Unique`) or shape math that
//! reads tensor contents (`ShapeExpr::Elem`) are ineligible and fall back
//! to solo execution, as does any batch whose residual bindings disagree.
//! See docs/runtime.md §Cross-request batching.

use crate::dhlo::{DType, Module, Op, ValueId};
use crate::library::{GemmSrc, WeightKey};
use crate::program::{Program, Step};
use crate::runtime::executor::{crop_box, pad_box, weight_ref_of, ExecOutput, Executor};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::plan::binding_vector;
use crate::runtime::reference::eval_op;
use crate::runtime::shape_env::{NoVals, SymEnv};
use crate::runtime::tensor::{Data, Tensor};
use crate::shape::{Dim, ShapeExpr, SymId};
use anyhow::{Context, Result};
use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

/// How one step of the generated flow executes inside a batched dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Executed once over values stacked along the leading batch symbol.
    Stacked,
    /// Derived from constants only: executed once, shared by all members.
    Shared,
    /// Executed once per member request (the solo semantics).
    PerRequest,
}

/// Result of the static batchability analysis of one program.
#[derive(Debug)]
pub struct BatchAnalysis {
    /// The canonical leading symbol requests stack along; `None` means the
    /// program is ineligible (see `reason`) and batches run solo.
    pub batch_sym: Option<SymId>,
    /// Why the program is ineligible (diagnostic; `None` when eligible).
    pub reason: Option<&'static str>,
    /// Execution mode per `Program::steps` entry (empty when ineligible).
    pub step_modes: Vec<BatchMode>,
    /// Mode of each IR value's materialized form (indexed by `ValueId`).
    pub value_modes: Vec<BatchMode>,
    /// Number of launch-carrying steps that run stacked (the win).
    pub stacked_steps: usize,
}

impl BatchAnalysis {
    pub fn eligible(&self) -> bool {
        self.batch_sym.is_some()
    }

    fn ineligible(reason: &'static str) -> BatchAnalysis {
        BatchAnalysis {
            batch_sym: None,
            reason: Some(reason),
            step_modes: Vec::new(),
            value_modes: Vec::new(),
            stacked_steps: 0,
        }
    }
}

/// Grouping key for batch assembly: the binding vector *minus* the leading
/// batch symbol. Requests may differ in their leading extent (that is the
/// axis batches stack along) but must agree on every other dynamic dim,
/// because stacked launches share one set of trailing extent scalars.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchKey {
    pub residual: Vec<(SymId, i64)>,
}

/// Compute the grouping key of a request, or `None` when the program is
/// ineligible or the inputs do not bind (such requests serve solo and
/// surface their errors through the normal run path).
pub fn group_key(m: &Module, analysis: &BatchAnalysis, inputs: &[Tensor]) -> Option<BatchKey> {
    let b = analysis.batch_sym?;
    let mut env = SymEnv::new();
    env.bind_params(m, inputs).ok()?;
    let mut residual = binding_vector(&env);
    let pos = residual.iter().position(|&(s, _)| s == b)?;
    residual.remove(pos);
    Some(BatchKey { residual })
}

/// Dims classification relative to the batch symbol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TyClass {
    /// No batch-tied symbol anywhere: identical across requests at fixed
    /// residual bindings.
    Free,
    /// Exactly the batch symbol, at axis 0 only: stackable by row concat.
    Lead,
    /// A batch-tied symbol somewhere else (or derived): never stackable.
    Tangled,
}

fn classify_dims(m: &Module, dims: &[Dim], b: SymId, tied: &HashSet<SymId>) -> TyClass {
    let mut lead = false;
    for (i, d) in dims.iter().enumerate() {
        if let Dim::Sym(s) = m.syms.canon_dim(*d) {
            if tied.contains(&s) {
                if i == 0 && s == b {
                    lead = true;
                } else {
                    return TyClass::Tangled;
                }
            }
        }
    }
    if lead {
        TyClass::Lead
    } else {
        TyClass::Free
    }
}

/// Does this shape expression read tensor contents (`Elem`) or
/// data-dependent extents (`DataDep`)? Either makes batched shape
/// resolution unsound (the stacked tensor's contents are not any single
/// request's), so such programs are ineligible.
fn expr_reads_values(e: &ShapeExpr) -> bool {
    let mut deps = Vec::new();
    e.value_deps(&mut deps);
    !deps.is_empty()
}

/// Is this expression's value coupled to the leading extent? `InputDim`
/// of axis 0 reads the (batched) leading extent directly; symbol
/// references couple through the tied set.
fn expr_tied(m: &Module, e: &ShapeExpr, tied: &HashSet<SymId>) -> bool {
    match e {
        ShapeExpr::InputDim { axis, .. } => *axis == 0,
        ShapeExpr::Dim(Dim::Sym(s)) => tied.contains(&m.syms.canon(*s)),
        ShapeExpr::Dim(Dim::Fixed(_)) | ShapeExpr::Const(_) => false,
        ShapeExpr::Elem { .. } | ShapeExpr::DataDep { .. } => false,
        ShapeExpr::Add(a, b2)
        | ShapeExpr::Sub(a, b2)
        | ShapeExpr::Mul(a, b2)
        | ShapeExpr::CeilDiv(a, b2)
        | ShapeExpr::Max(a, b2) => expr_tied(m, a, tied) || expr_tied(m, b2, tied),
    }
}

/// Does the op map axis 0 independently, given its operand placement?
/// `op_tys[i]` is the mode+class of operand `i` as materialized for the
/// stacked launch. Only called once the output is `Lead` and operands are
/// individually stackable.
fn op_maps_rows(
    m: &Module,
    op: &Op,
    operands: &[ValueId],
    op_tys: &[(BatchMode, TyClass)],
) -> bool {
    match op {
        Op::Un(_) | Op::Bin(_) | Op::Cmp(_) | Op::Select | Op::Convert(_) => true,
        // Broadcast maps operand axis i to output axis dims[i]: a stacked
        // operand must keep its rows on axis 0; a shared operand must not
        // be spread along axis 0 (that would index values by row position,
        // which differs between the stacked and solo layouts).
        Op::Broadcast { dims } => match op_tys[0].1 {
            TyClass::Lead => dims.first() == Some(&0),
            TyClass::Free => !dims.contains(&0),
            TyClass::Tangled => false,
        },
        Op::Transpose { perm } => perm.first() == Some(&0),
        // Row-preserving metadata reshape: both sides carry the batch at
        // axis 0, so per-row element counts match and rows stay intact.
        Op::Reshape => true,
        Op::Reduce { axes, .. } => !axes.contains(&0),
        Op::Concat { axis } => *axis != 0,
        // Embedding lookup: shared table, stacked indices — each output
        // row depends on one index row only.
        Op::Gather { .. } => {
            op_tys[0].1 == TyClass::Free
                && op_tys[0].0 == BatchMode::Shared
                && op_tys[1].1 == TyClass::Lead
        }
        // `[rows, k] · [k, n]` with a shared RHS is row-parallel;
        // `[b, m, k] · [b, k, n]` with both sides stacked along the batch
        // axis is slice-parallel.
        Op::Dot => {
            let lhs_rank = m.instrs[operands[0]].ty.dims.len();
            match op_tys[1].0 {
                BatchMode::Shared => lhs_rank == 2 && op_tys[1].1 == TyClass::Free,
                _ => lhs_rank == 3 && op_tys[1].1 == TyClass::Lead,
            }
        }
        // Slices/pads/dynamic twins/iota/dim reads either address rows by
        // absolute position or read extents: per-request only.
        _ => false,
    }
}

/// Classify one value-defining step outside fusion groups.
fn classify_value_step(
    m: &Module,
    v: ValueId,
    modes: &[BatchMode],
    b: SymId,
    tied: &HashSet<SymId>,
) -> BatchMode {
    let ins = &m.instrs[v];
    let out = classify_dims(m, &ins.ty.dims, b, tied);
    let op_tys: Vec<(BatchMode, TyClass)> = ins
        .operands
        .iter()
        .map(|&o| (modes[o], classify_dims(m, &m.instrs[o].ty.dims, b, tied)))
        .collect();
    if out == TyClass::Free && op_tys.iter().all(|&(mo, _)| mo == BatchMode::Shared) {
        return BatchMode::Shared;
    }
    // A stacked launch can consume shared (request-independent) values and
    // anything with the batch cleanly at axis 0 — per-request values with a
    // Lead type are concatenated on demand.
    let operands_ok = op_tys.iter().all(|&(mo, tc)| match mo {
        BatchMode::Shared => tc == TyClass::Free,
        BatchMode::Stacked | BatchMode::PerRequest => tc == TyClass::Lead,
    });
    if out == TyClass::Lead && operands_ok && op_maps_rows(m, &ins.op, &ins.operands, &op_tys) {
        BatchMode::Stacked
    } else {
        BatchMode::PerRequest
    }
}

/// Classify a fused-kernel launch: every member must map rows
/// independently for the group to run stacked.
fn classify_group(
    m: &Module,
    fl: &crate::program::FusedLaunch,
    modes: &[BatchMode],
    b: SymId,
    tied: &HashSet<SymId>,
) -> BatchMode {
    let root = classify_dims(m, &m.ty(fl.root).dims, b, tied);
    let in_tys: Vec<(BatchMode, TyClass)> = fl
        .inputs
        .iter()
        .map(|&v| (modes[v], classify_dims(m, &m.instrs[v].ty.dims, b, tied)))
        .collect();
    if root == TyClass::Free && in_tys.iter().all(|&(mo, _)| mo == BatchMode::Shared) {
        return BatchMode::Shared;
    }
    let inputs_ok = in_tys.iter().all(|&(mo, tc)| match mo {
        BatchMode::Shared => tc == TyClass::Free,
        BatchMode::Stacked | BatchMode::PerRequest => tc == TyClass::Lead,
    });
    if root != TyClass::Lead || !inputs_ok {
        return BatchMode::PerRequest;
    }
    // Interior members: type-driven (classes exist only for externals).
    for &mv in &fl.group.members {
        let ins = &m.instrs[mv];
        let out_c = classify_dims(m, &ins.ty.dims, b, tied);
        let op_cs: Vec<TyClass> = ins
            .operands
            .iter()
            .map(|&o| classify_dims(m, &m.instrs[o].ty.dims, b, tied))
            .collect();
        if out_c == TyClass::Tangled || op_cs.contains(&TyClass::Tangled) {
            return BatchMode::PerRequest;
        }
        if out_c == TyClass::Free {
            if op_cs.contains(&TyClass::Lead) {
                // Dropping the batch axis inside the kernel couples rows.
                return BatchMode::PerRequest;
            }
            continue;
        }
        let ok = match &ins.op {
            Op::Un(_) | Op::Bin(_) | Op::Cmp(_) | Op::Select | Op::Convert(_) => true,
            Op::Broadcast { dims } => match op_cs[0] {
                TyClass::Lead => dims.first() == Some(&0),
                TyClass::Free => !dims.contains(&0),
                TyClass::Tangled => false,
            },
            Op::Transpose { perm } => perm.first() == Some(&0),
            Op::Reduce { axes, .. } => !axes.contains(&0),
            // Externals (params) appearing as members keep their rows.
            Op::Param { .. } => true,
            _ => false,
        };
        if !ok {
            return BatchMode::PerRequest;
        }
    }
    BatchMode::Stacked
}

/// Statically analyze a program for cross-request batchability. Pure
/// shape/dataflow reasoning — no inputs involved — so the result is
/// computed once per program and cached by the executor.
pub fn analyze(prog: &Program) -> BatchAnalysis {
    let m = &prog.module;

    // The leading batch symbol: every entry parameter must carry it at
    // axis 0 (otherwise a parameter would have to be bit-identical across
    // batch members, which the coordinator cannot know).
    let b = match m.params.first().and_then(|ty| ty.dims.first()) {
        Some(&d) => match m.syms.canon_dim(d) {
            Dim::Sym(s) => s,
            Dim::Fixed(_) => {
                return BatchAnalysis::ineligible("first parameter has a static leading dim")
            }
        },
        None => return BatchAnalysis::ineligible("program has no parameters to stack"),
    };
    for ty in &m.params {
        match ty.dims.first().map(|&d| m.syms.canon_dim(d)) {
            Some(Dim::Sym(s)) if s == b => {}
            _ => {
                return BatchAnalysis::ineligible(
                    "parameters do not share one leading dynamic symbol",
                )
            }
        }
    }
    if m.instrs.iter().any(|i| matches!(i.op, Op::Unique)) {
        return BatchAnalysis::ineligible("data-dependent extents (unique)");
    }

    // Symbols actually used by instruction types, transitively through
    // their definitions (only canonical representatives resolve at
    // runtime). Reject content-dependent shape math outright.
    let mut used: HashSet<SymId> = HashSet::new();
    let mut stack: Vec<SymId> = Vec::new();
    for ins in &m.instrs {
        for &d in &ins.ty.dims {
            if let Dim::Sym(s) = m.syms.canon_dim(d) {
                stack.push(s);
            }
        }
    }
    while let Some(s) = stack.pop() {
        if !used.insert(s) {
            continue;
        }
        let mut deps = Vec::new();
        m.syms.def(s).deps(&mut deps);
        for d in deps {
            stack.push(m.syms.canon(d));
        }
    }
    for &s in &used {
        if expr_reads_values(m.syms.def(s)) {
            return BatchAnalysis::ineligible("shape math reads tensor contents");
        }
    }

    // Symbols whose value is coupled to the leading extent (the batch
    // symbol itself, anything derived from it, anything reading a
    // parameter's axis-0 extent).
    let mut tied: HashSet<SymId> = HashSet::new();
    tied.insert(b);
    loop {
        let mut changed = false;
        for &s in &used {
            if !tied.contains(&s) && expr_tied(m, m.syms.def(s), &tied) {
                tied.insert(s);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    for ty in &m.params {
        if classify_dims(m, &ty.dims, b, &tied) != TyClass::Lead {
            return BatchAnalysis::ineligible("parameter entangled beyond its leading dim");
        }
    }

    // Dataflow pass over the step sequence.
    let n = m.instrs.len();
    let mut value_modes = vec![BatchMode::PerRequest; n];
    for (id, ins) in m.instrs.iter().enumerate() {
        match ins.op {
            Op::Const { .. } => value_modes[id] = BatchMode::Shared,
            Op::Param { .. } => value_modes[id] = BatchMode::Stacked,
            _ => {}
        }
    }
    let mut step_modes = Vec::with_capacity(prog.steps.len());
    let mut stacked_steps = 0usize;
    for step in &prog.steps {
        let mode = match step {
            Step::Dealloc { .. } => BatchMode::Shared,
            Step::EvalHost { value }
            | Step::Bitcast { value }
            | Step::LaunchOp { value }
            | Step::LibraryCall { value } => {
                let mo = classify_value_step(m, *value, &value_modes, b, &tied);
                value_modes[*value] = mo;
                mo
            }
            Step::LaunchFused { idx } => {
                let fl = &prog.fused[*idx];
                let mo = classify_group(m, fl, &value_modes, b, &tied);
                value_modes[fl.root] = mo;
                mo
            }
        };
        if mode == BatchMode::Stacked
            && matches!(
                step,
                Step::LaunchFused { .. } | Step::LaunchOp { .. } | Step::LibraryCall { .. }
            )
        {
            stacked_steps += 1;
        }
        step_modes.push(mode);
    }
    if stacked_steps == 0 {
        return BatchAnalysis::ineligible("no leading-parallel launches to batch");
    }

    BatchAnalysis {
        batch_sym: Some(b),
        reason: None,
        step_modes,
        value_modes,
        stacked_steps,
    }
}

/// Per-request results of one batched dispatch.
pub struct BatchOutput {
    /// `outputs[i]` holds request `i`'s program outputs, bit-identical to
    /// what a solo run of that request would produce.
    pub outputs: Vec<Vec<Tensor>>,
    /// Aggregate metrics of the whole dispatch (launch counts cover the
    /// batch once, which is the point).
    pub metrics: RunMetrics,
}

/// Materialize the stacked (or shared) form of a value: either already in
/// the joint store, or assembled by concatenating the per-request parts.
fn joint_value(
    joint: &mut [Option<Rc<Tensor>>],
    per: &[Option<Vec<Rc<Tensor>>>],
    metrics: &mut RunMetrics,
    v: ValueId,
) -> Result<Rc<Tensor>> {
    if let Some(t) = &joint[v] {
        return Ok(t.clone());
    }
    let parts = per[v]
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("value %{v} has no live batched form"))?;
    let refs: Vec<&Tensor> = parts.iter().map(|r| r.as_ref()).collect();
    let t = Tensor::concat0(&refs).with_context(|| format!("stacking value %{v}"))?;
    metrics.batch_stack_bytes += t.byte_size() as u64;
    let rc = Rc::new(t);
    joint[v] = Some(rc.clone());
    Ok(rc)
}

/// Materialize request `i`'s view of a value: the per-request slot, the
/// shared tensor, or a row slice of the stacked form.
fn per_value(
    joint: &[Option<Rc<Tensor>>],
    per: &mut [Option<Vec<Rc<Tensor>>>],
    analysis: &BatchAnalysis,
    offsets: &[usize],
    metrics: &mut RunMetrics,
    v: ValueId,
    i: usize,
) -> Result<Rc<Tensor>> {
    if let Some(parts) = &per[v] {
        return Ok(parts[i].clone());
    }
    let t = joint[v]
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("value %{v} has no live batched form"))?;
    if analysis.value_modes[v] == BatchMode::Shared {
        return Ok(t.clone());
    }
    // Slice every member at once (contiguous leading-axis ranges).
    let k = offsets.len() - 1;
    let mut parts = Vec::with_capacity(k);
    for j in 0..k {
        let rows = offsets[j + 1] - offsets[j];
        let s = t
            .slice0(offsets[j], rows)
            .with_context(|| format!("splitting value %{v} for request {j}"))?;
        metrics.batch_stack_bytes += s.byte_size() as u64;
        parts.push(Rc::new(s));
    }
    let out = parts[i].clone();
    per[v] = Some(parts);
    Ok(out)
}

impl Executor {
    /// The (cached) batchability analysis of a program.
    pub fn batch_analysis(&mut self, prog: &Program) -> Arc<BatchAnalysis> {
        self.batch_info
            .entry(prog.id)
            .or_insert_with(|| Arc::new(analyze(prog)))
            .clone()
    }

    /// Execute several requests as one batched dispatch (see the module
    /// docs). Outputs are bit-identical to solo runs. Falls back to
    /// sequential solo execution for singletons, ineligible programs, and
    /// batches whose residual bindings disagree (requests that cannot even
    /// bind fall back too, so their errors surface through the normal solo
    /// run path).
    pub fn run_batch(&mut self, prog: &Program, requests: &[Vec<Tensor>]) -> Result<BatchOutput> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let analysis = self.batch_analysis(prog);
        if requests.len() > 1 && analysis.eligible() {
            // The stacked walk validates residual-binding agreement from
            // the member environments it binds anyway (no extra key
            // derivation on the hot path) and declines mismatched groups.
            if let Some(out) = self.run_stacked(prog, requests, &analysis)? {
                return Ok(out);
            }
        }
        let mut outputs = Vec::with_capacity(requests.len());
        let mut metrics = RunMetrics::default();
        for r in requests {
            let ExecOutput { outputs: o, metrics: rm } = self.run(prog, r)?;
            metrics += &rm;
            outputs.push(o);
        }
        Ok(BatchOutput { outputs, metrics })
    }

    /// The batched walk proper. `analysis` is known-eligible; returns
    /// `Ok(None)` when the group cannot stack after all (unbindable member
    /// inputs, or residual bindings that disagree) — the caller then serves
    /// the members solo.
    fn run_stacked(
        &mut self,
        prog: &Program,
        requests: &[Vec<Tensor>],
        analysis: &BatchAnalysis,
    ) -> Result<Option<BatchOutput>> {
        let t_start = Instant::now();
        let m = &prog.module;
        let k = requests.len();
        let b_sym = analysis.batch_sym.expect("caller checked eligibility");
        let mut metrics = RunMetrics::default();
        let before = self.stats_snapshot();

        // Per-request environments and leading extents; the residual
        // bindings (everything except the leading symbol) must agree
        // across members, because stacked launches share one set of
        // trailing extent scalars.
        let mut envs = Vec::with_capacity(k);
        let mut offsets = Vec::with_capacity(k + 1);
        let mut residual0: Option<Vec<(SymId, i64)>> = None;
        offsets.push(0usize);
        for (i, r) in requests.iter().enumerate() {
            let mut e = SymEnv::new();
            if e.bind_params(m, r).is_err() {
                return Ok(None);
            }
            let Some(&ext) = e.resolved().get(&b_sym) else {
                return Ok(None);
            };
            let mut residual = binding_vector(&e);
            residual.retain(|&(s, _)| s != b_sym);
            match &residual0 {
                None => residual0 = Some(residual),
                Some(first) if first != &residual => return Ok(None),
                Some(_) => {}
            }
            offsets.push(offsets[i] + ext as usize);
            envs.push(e);
        }

        // Stack the entry parameters and bind the batched environment.
        let mut stacked: Vec<Tensor> = Vec::with_capacity(m.params.len());
        for p in 0..m.params.len() {
            let parts: Vec<&Tensor> = requests.iter().map(|r| &r[p]).collect();
            let t = Tensor::concat0(&parts).with_context(|| format!("stacking param {p}"))?;
            metrics.batch_stack_bytes += t.byte_size() as u64;
            stacked.push(t);
        }
        let mut env_b = SymEnv::new();
        env_b.bind_params(m, &stacked)?;

        // Value stores: stacked/shared forms plus per-request forms.
        let n = m.instrs.len();
        let mut joint: Vec<Option<Rc<Tensor>>> = vec![None; n];
        let mut per: Vec<Option<Vec<Rc<Tensor>>>> = vec![None; n];
        let mut stacked_slots: Vec<Option<Tensor>> = stacked.into_iter().map(Some).collect();
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => {
                    joint[id] = stacked_slots[*index].take().map(Rc::new);
                }
                Op::Const { lit, dims } => {
                    joint[id] = Some(Rc::new(Tensor::from_literal(lit, dims)));
                }
                _ => {}
            }
        }

        for (si, step) in prog.steps.iter().enumerate() {
            let mode = analysis.step_modes[si];
            match step {
                Step::Dealloc { value } => {
                    joint[*value] = None;
                    per[*value] = None;
                }
                _ if mode != BatchMode::PerRequest => {
                    self.stacked_step(
                        prog,
                        step,
                        mode,
                        &mut env_b,
                        &mut joint,
                        &per,
                        &mut metrics,
                    )?;
                }
                _ => {
                    self.solo_step(
                        prog,
                        step,
                        &mut envs,
                        &joint,
                        &mut per,
                        offsets.as_slice(),
                        analysis,
                        &mut metrics,
                    )?;
                }
            }
        }

        // Split per-request outputs back out.
        let mut outputs: Vec<Vec<Tensor>> =
            (0..k).map(|_| Vec::with_capacity(m.outputs.len())).collect();
        for &o in &m.outputs {
            for (i, out) in outputs.iter_mut().enumerate() {
                let t = per_value(&joint, &mut per, analysis, &offsets, &mut metrics, o, i)
                    .with_context(|| format!("output %{o} was deallocated"))?;
                out.push((*t).clone());
            }
        }

        self.fold_stats(&mut metrics, &before);
        metrics.batched_requests += k as u64;
        metrics.batched_launches += 1;
        metrics.total_time = t_start.elapsed();
        Ok(Some(BatchOutput { outputs, metrics }))
    }

    /// One GEMM library call on already-materialized operands, routing
    /// constant weights through the persistent device-side cache — the
    /// shared body of the stacked and per-member batched paths (the
    /// recorder-integrated interpret tier keeps its own copy, which also
    /// serves fingerprint-validated parameter weights).
    fn batched_gemm(
        &mut self,
        prog: &Program,
        value: ValueId,
        a: &Tensor,
        bt: &Tensor,
        metrics: &mut RunMetrics,
    ) -> Result<Tensor> {
        let m = &prog.module;
        let ins = &m.instrs[value];
        metrics.lib_bytes += (a.byte_size() + bt.byte_size()) as u64;
        let build0 = self.library.stats.build_time;
        let exec0 = self.library.stats.exec_time;
        let key = self.library.key_for(a, bt)?;
        // Constant weights ride the persistent device-side cache — the
        // same entries solo runs populate. Parameter weights can be
        // stacked per batch, so they take the plain host path.
        let weight = if self.opts.device_resident && self.opts.weight_cache {
            weight_ref_of(m, ins.operands[1]).filter(|w| !w.validate && bt.dtype == DType::F32)
        } else {
            None
        };
        let t = if let Some(w) = &weight {
            let wdev = self.library.weight_device(
                WeightKey { program: prog.id, value: w.value },
                bt,
                &key.rhs_dims(),
                w.validate,
            )?;
            let (dt, actual) = self.library.matmul_device(
                GemmSrc::Host(a),
                GemmSrc::Weight { dt: wdev, actual: &bt.dims },
                key,
            )?;
            self.library.readback(&dt, &actual)?
        } else {
            self.library.matmul_with_key(a, bt, key)?
        };
        metrics.lib_time += self.library.stats.exec_time - exec0;
        metrics.compile_time += self.library.stats.build_time - build0;
        metrics.lib_calls += 1;
        metrics.lib_bytes += t.byte_size() as u64;
        Ok(t)
    }

    /// One fused-kernel launch on already-materialized inputs: resolve the
    /// group's extents through `env`, fetch the bucket-keyed kernel, pad,
    /// launch, crop — the shared body of the stacked and per-member
    /// batched paths. Stacked launches are keyed by the *widened* leading
    /// extent, so a batch rides the same (signature, bucket) family solo
    /// traffic compiles; `count_padding` additionally accounts pad-lane
    /// traffic into `batch_padding_bytes` for them.
    fn batched_fused(
        &mut self,
        prog: &Program,
        idx: usize,
        env: &mut SymEnv,
        inputs: &[Rc<Tensor>],
        count_padding: bool,
        metrics: &mut RunMetrics,
    ) -> Result<Tensor> {
        let m = &prog.module;
        let fl = &prog.fused[idx];
        let mut actual: HashMap<SymId, usize> = HashMap::with_capacity(fl.syms.len());
        for &s in &fl.syms {
            actual.insert(s, env.resolve_dim(m, Dim::Sym(s), &NoVals)?);
        }
        let (kernel, _buckets) = self.cache.get_or_compile(m, &fl.group, &fl.sig, &actual)?;
        let spec = &kernel.spec;
        enum Src {
            In(usize),
            Owned(usize),
        }
        let mut owned: Vec<Tensor> = Vec::new();
        let mut srcs: Vec<Src> = Vec::with_capacity(inputs.len() + spec.extent_locals.len());
        for (i, src) in inputs.iter().enumerate() {
            if src.dims == spec.input_dims[i] {
                srcs.push(Src::In(i));
                metrics.mem_bytes += src.byte_size() as u64;
            } else {
                metrics.pad_copies += 1;
                let padded = pad_box(
                    src,
                    &spec.input_dims[i],
                    if self.opts.pooled_buffers { Some(&mut self.pool) } else { None },
                )?;
                metrics.mem_bytes += padded.byte_size() as u64;
                if count_padding {
                    metrics.batch_padding_bytes += (padded.byte_size() - src.byte_size()) as u64;
                }
                srcs.push(Src::Owned(owned.len()));
                owned.push(padded);
            }
        }
        for &li in &spec.extent_locals {
            let v = actual[&fl.syms[li]];
            srcs.push(Src::Owned(owned.len()));
            owned.push(Tensor::i32(&[], vec![v as i32]));
        }
        let args: Vec<&Tensor> = srcs
            .iter()
            .map(|s| match s {
                Src::In(i) => inputs[*i].as_ref(),
                Src::Owned(i) => &owned[*i],
            })
            .collect();
        for a in &args {
            metrics.h2d_bytes += a.byte_size() as u64;
        }
        let tk = Instant::now();
        let out = kernel
            .exe
            .run(&args, &spec.out_dims, spec.out_dtype)
            .with_context(|| format!("launching fused kernel {} (batched)", spec.name))?;
        metrics.kernel_time += tk.elapsed();
        metrics.mem_kernels += 1;
        drop(args);
        if self.opts.pooled_buffers {
            for a in owned {
                if let Data::F32(v) = a.data {
                    if v.capacity() > 0 {
                        self.pool.free_f32(v);
                    }
                }
            }
        }
        metrics.mem_bytes += out.byte_size() as u64;
        metrics.d2h_bytes += out.byte_size() as u64;
        let actual_out = env.resolve_dims(m, &m.ty(fl.root).dims, &NoVals)?;
        if out.dims == actual_out {
            Ok(out)
        } else {
            metrics.pad_copies += 1;
            if count_padding {
                metrics.batch_padding_bytes += (out.byte_size()
                    - actual_out.iter().product::<usize>() * spec.out_dtype.byte_size())
                    as u64;
            }
            crop_box(&out, &actual_out)
        }
    }

    /// Execute one Stacked/Shared step over the joint value store.
    #[allow(clippy::too_many_arguments)]
    fn stacked_step(
        &mut self,
        prog: &Program,
        step: &Step,
        mode: BatchMode,
        env_b: &mut SymEnv,
        joint: &mut [Option<Rc<Tensor>>],
        per: &[Option<Vec<Rc<Tensor>>>],
        metrics: &mut RunMetrics,
    ) -> Result<()> {
        let m = &prog.module;
        match step {
            Step::EvalHost { value } => {
                let ins = &m.instrs[*value];
                let out_dims = env_b.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                let ops: Vec<Rc<Tensor>> = ins
                    .operands
                    .iter()
                    .map(|&o| joint_value(joint, per, metrics, o))
                    .collect::<Result<_>>()?;
                let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                let t = eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                    .with_context(|| format!("host op %{value} (batched)"))?;
                metrics.host_ops += 1;
                joint[*value] = Some(Rc::new(t));
            }
            Step::Bitcast { value } => {
                let ins = &m.instrs[*value];
                let out_dims = env_b.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                let src = joint_value(joint, per, metrics, ins.operands[0])?;
                metrics.bitcasts += 1;
                joint[*value] = Some(Rc::new((*src).clone().with_dims(&out_dims)?));
            }
            Step::LaunchOp { value } => {
                let ins = &m.instrs[*value];
                let out_dims = env_b.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                let ops: Vec<Rc<Tensor>> = ins
                    .operands
                    .iter()
                    .map(|&o| joint_value(joint, per, metrics, o))
                    .collect::<Result<_>>()?;
                let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                for o in &refs {
                    metrics.mem_bytes += o.byte_size() as u64;
                }
                let tk = Instant::now();
                let t = eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                    .with_context(|| format!("singleton kernel %{value} (batched)"))?;
                metrics.kernel_time += tk.elapsed();
                metrics.mem_kernels += 1;
                metrics.mem_bytes += t.byte_size() as u64;
                joint[*value] = Some(Rc::new(t));
            }
            Step::LibraryCall { value } => {
                let ins = &m.instrs[*value];
                let a = joint_value(joint, per, metrics, ins.operands[0])?;
                let bt = joint_value(joint, per, metrics, ins.operands[1])?;
                let t = self.batched_gemm(prog, *value, &a, &bt, metrics)?;
                joint[*value] = Some(Rc::new(t));
            }
            Step::LaunchFused { idx } => {
                let fl = &prog.fused[*idx];
                let ins_rc: Vec<Rc<Tensor>> = fl
                    .inputs
                    .iter()
                    .map(|&v| joint_value(joint, per, metrics, v))
                    .collect::<Result<_>>()?;
                let out = self.batched_fused(
                    prog,
                    *idx,
                    env_b,
                    &ins_rc,
                    mode == BatchMode::Stacked,
                    metrics,
                )?;
                joint[fl.root] = Some(Rc::new(out));
            }
            Step::Dealloc { .. } => unreachable!("handled by the caller"),
        }
        Ok(())
    }

    /// Execute one PerRequest step: once per batch member, with that
    /// member's own environment — exactly the solo interpret semantics.
    #[allow(clippy::too_many_arguments)]
    fn solo_step(
        &mut self,
        prog: &Program,
        step: &Step,
        envs: &mut [SymEnv],
        joint: &[Option<Rc<Tensor>>],
        per: &mut [Option<Vec<Rc<Tensor>>>],
        offsets: &[usize],
        analysis: &BatchAnalysis,
        metrics: &mut RunMetrics,
    ) -> Result<()> {
        let m = &prog.module;
        let k = envs.len();
        let value = match step {
            Step::EvalHost { value }
            | Step::Bitcast { value }
            | Step::LaunchOp { value }
            | Step::LibraryCall { value } => *value,
            Step::LaunchFused { idx } => prog.fused[*idx].root,
            Step::Dealloc { .. } => unreachable!("handled by the caller"),
        };
        let mut results: Vec<Rc<Tensor>> = Vec::with_capacity(k);
        for i in 0..k {
            let env = &mut envs[i];
            let t = match step {
                Step::EvalHost { value } | Step::LaunchOp { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                    let ops: Vec<Rc<Tensor>> = ins
                        .operands
                        .iter()
                        .map(|&o| per_value(joint, per, analysis, offsets, metrics, o, i))
                        .collect::<Result<_>>()?;
                    let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                    if matches!(step, Step::LaunchOp { .. }) {
                        for o in &refs {
                            metrics.mem_bytes += o.byte_size() as u64;
                        }
                        let tk = Instant::now();
                        let t = eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                            .with_context(|| format!("singleton kernel %{value} (member {i})"))?;
                        metrics.kernel_time += tk.elapsed();
                        metrics.mem_kernels += 1;
                        metrics.mem_bytes += t.byte_size() as u64;
                        t
                    } else {
                        metrics.host_ops += 1;
                        eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                            .with_context(|| format!("host op %{value} (member {i})"))?
                    }
                }
                Step::Bitcast { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                    let src =
                        per_value(joint, per, analysis, offsets, metrics, ins.operands[0], i)?;
                    metrics.bitcasts += 1;
                    (*src).clone().with_dims(&out_dims)?
                }
                Step::LibraryCall { value } => {
                    let ins = &m.instrs[*value];
                    let a = per_value(joint, per, analysis, offsets, metrics, ins.operands[0], i)?;
                    let bt = per_value(joint, per, analysis, offsets, metrics, ins.operands[1], i)?;
                    self.batched_gemm(prog, *value, &a, &bt, metrics)
                        .with_context(|| format!("library call %{value} (member {i})"))?
                }
                Step::LaunchFused { idx } => {
                    let fl = &prog.fused[*idx];
                    let ins_rc: Vec<Rc<Tensor>> = fl
                        .inputs
                        .iter()
                        .map(|&v| per_value(joint, per, analysis, offsets, metrics, v, i))
                        .collect::<Result<_>>()?;
                    self.batched_fused(prog, *idx, env, &ins_rc, false, metrics)
                        .with_context(|| format!("fused launch {idx} (member {i})"))?
                }
                Step::Dealloc { .. } => unreachable!("handled by the caller"),
            };
            results.push(Rc::new(t));
        }
        per[value] = Some(results);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::Builder;
    use crate::fusion::{plan, FusionOptions};
    use crate::program::generate;
    use crate::runtime::executor::ExecOptions;
    use crate::runtime::pjrt::Device;
    use crate::util::prng::Prng;

    fn executor() -> Executor {
        Executor::new(Arc::new(Device::cpu().unwrap()), ExecOptions::default())
    }

    fn program_of(m: Module) -> Program {
        let p = plan(&m, &FusionOptions::default());
        generate(m, &p).unwrap()
    }

    /// `softmax(x)` over a fixed trailing axis: fully row-parallel.
    fn row_softmax_prog() -> Program {
        let mut b = Builder::new("rows");
        let s = b.dyn_dim("rows", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let y = b.softmax_last(x).unwrap();
        program_of(b.finish(vec![y]))
    }

    /// `softmax(x)` with rows *and* cols dynamic: the cols binding is the
    /// residual grouping key.
    fn two_sym_prog() -> Program {
        let mut b = Builder::new("rc");
        let s = b.dyn_dim("rows", 0, 0);
        let c = b.dyn_dim("cols", 0, 1);
        let x = b.param(DType::F32, vec![s, c]);
        let y = b.softmax_last(x).unwrap();
        program_of(b.finish(vec![y]))
    }

    fn transformer_prog() -> Program {
        let w = crate::workloads::transformer::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let m = crate::passes::optimize(&m).unwrap();
        program_of(m)
    }

    #[test]
    fn analysis_accepts_row_parallel_programs() {
        let prog = row_softmax_prog();
        let a = analyze(&prog);
        assert!(a.eligible(), "row softmax must be batchable: {:?}", a.reason);
        assert!(a.stacked_steps > 0);
    }

    #[test]
    fn analysis_classifies_transformer_attention_per_request() {
        let prog = transformer_prog();
        let a = analyze(&prog);
        assert!(a.eligible(), "transformer must be batchable: {:?}", a.reason);
        assert!(a.stacked_steps > 0, "projections/FFN/layernorms must stack");
        // Attention mixes rows across the dynamic axis, so some launches
        // must stay per-request — if everything stacked, the analysis
        // would be unsound for `[heads, s, s]` scores.
        assert!(
            a.step_modes.iter().any(|&mo| mo == BatchMode::PerRequest),
            "attention core must run per request"
        );
    }

    #[test]
    fn analysis_rejects_static_leading_params_and_unique() {
        // TTS carries a `[1, MEL]` parameter: no shared leading symbol.
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let a = analyze(&program_of(crate::passes::optimize(&m).unwrap()));
        assert!(!a.eligible());
        assert!(a.reason.is_some());

        // Unique's data-dependent extent is never batchable.
        let mut b = Builder::new("sparse");
        let n = b.dyn_dim("n", 0, 0);
        let ids = b.param(crate::dhlo::DType::I64, vec![n]);
        let u = b.unique(ids).unwrap();
        let a = analyze(&program_of(b.finish(vec![u])));
        assert_eq!(a.reason, Some("data-dependent extents (unique)"));
    }

    #[test]
    fn group_key_strips_the_batch_symbol() {
        let prog = two_sym_prog();
        let a = analyze(&prog);
        assert!(a.eligible(), "{:?}", a.reason);
        let m = &prog.module;
        let t = |rows: usize, cols: usize| {
            vec![Tensor::f32(&[rows, cols], vec![0.1; rows * cols])]
        };
        let k25 = group_key(m, &a, &t(2, 5)).unwrap();
        let k35 = group_key(m, &a, &t(3, 5)).unwrap();
        let k26 = group_key(m, &a, &t(2, 6)).unwrap();
        assert_eq!(k25, k35, "leading extent must not split groups");
        assert_ne!(k25, k26, "residual bindings must split groups");
        // Unbindable inputs yield no key (the request serves solo).
        assert!(group_key(m, &a, &[]).is_none());
    }

    #[test]
    fn run_batch_bit_matches_solo_on_transformer() {
        let prog = transformer_prog();
        let mut batched = executor();
        let mut solo = executor();
        let mut rng = Prng::new(5);
        let requests: Vec<Vec<Tensor>> = [6usize, 9, 12]
            .iter()
            .map(|&s| crate::workloads::transformer::gen_inputs(s, &mut rng))
            .collect();

        let want: Vec<(Vec<Tensor>, u64)> = requests
            .iter()
            .map(|r| {
                let o = solo.run(&prog, r).unwrap();
                (o.outputs, o.metrics.total_kernels())
            })
            .collect();
        let solo_kernels: u64 = want.iter().map(|(_, k)| k).sum();

        let out = batched.run_batch(&prog, &requests).unwrap();
        assert_eq!(out.outputs.len(), 3);
        for (got, (expect, _)) in out.outputs.iter().zip(&want) {
            assert_eq!(got, expect, "batched outputs diverged from solo runs");
        }
        assert_eq!(out.metrics.batched_requests, 3);
        assert_eq!(out.metrics.batched_launches, 1);
        assert!(
            out.metrics.total_kernels() < solo_kernels,
            "batch must launch fewer kernels ({} vs {} solo)",
            out.metrics.total_kernels(),
            solo_kernels
        );
    }

    #[test]
    fn run_batch_falls_back_for_singletons_and_mismatched_bindings() {
        let prog = two_sym_prog();
        let mut exec = executor();
        let mut rng = Prng::new(9);
        let t = |rows: usize, cols: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, cols], rng.fill_f32(rows * cols, 1.0))]
        };

        // Singleton: plain solo run.
        let one = vec![t(3, 5, &mut rng)];
        let out = exec.run_batch(&prog, &one).unwrap();
        assert_eq!(out.metrics.batched_launches, 0);
        assert_eq!(out.outputs.len(), 1);

        // Residual mismatch (different cols): sequential solo fallback,
        // still correct per request.
        let reqs = vec![t(2, 5, &mut rng), t(2, 6, &mut rng)];
        let out = exec.run_batch(&prog, &reqs).unwrap();
        assert_eq!(out.metrics.batched_launches, 0, "mismatched bindings must not stack");
        assert_eq!(out.outputs[0][0].dims, vec![2, 5]);
        assert_eq!(out.outputs[1][0].dims, vec![2, 6]);
        let mut solo = executor();
        for (r, o) in reqs.iter().zip(&out.outputs) {
            assert_eq!(&solo.run(&prog, r).unwrap().outputs, o);
        }
    }

    #[test]
    fn batch_rides_the_bucket_a_solo_request_compiled() {
        // NextPow2: a solo request at 5 rows compiles the bucket-8 kernel;
        // a batch of three requests totalling 5 rows lands in the SAME
        // bucket — zero new compiles, shared key family (the batch-bucket
        // key property).
        let prog = row_softmax_prog();
        let mut exec = executor();
        let mut rng = Prng::new(11);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };
        exec.run(&prog, &t(5, &mut rng)).unwrap();
        let misses = exec.cache.stats.misses;
        assert!(misses > 0);

        let reqs = vec![t(1, &mut rng), t(2, &mut rng), t(2, &mut rng)];
        let out = exec.run_batch(&prog, &reqs).unwrap();
        assert_eq!(out.metrics.batched_launches, 1);
        assert_eq!(out.metrics.compile_events, 0, "batch must reuse the bucket-8 kernel");
        assert_eq!(exec.cache.stats.misses, misses);
        // And solo references stay bit-exact.
        let mut solo = executor();
        for (r, o) in reqs.iter().zip(&out.outputs) {
            assert_eq!(&solo.run(&prog, r).unwrap().outputs, o);
        }
    }

    #[test]
    fn batched_outputs_split_at_request_boundaries() {
        let prog = row_softmax_prog();
        let mut exec = executor();
        let mut rng = Prng::new(13);
        let reqs: Vec<Vec<Tensor>> = [3usize, 1, 4]
            .iter()
            .map(|&r| vec![Tensor::f32(&[r, 8], rng.fill_f32(r * 8, 1.0))])
            .collect();
        let out = exec.run_batch(&prog, &reqs).unwrap();
        for (req, outs) in reqs.iter().zip(&out.outputs) {
            assert_eq!(outs[0].dims, req[0].dims, "per-request extents restored");
        }
        assert!(out.metrics.batch_stack_bytes > 0, "stacking traffic is accounted");
    }
}
